//! Analytic models and trace analysis: the gamma survival fit of Fig. 3
//! and the scalability projection of Fig. 13.

use crate::config::ClusterConfig;
use crate::pls;
use crate::util::dist::gamma_survival;
use crate::util::stats;

/// Fig. 3a: fit observed times-to-failure with a gamma distribution and
/// report the RMSE between fitted and empirical survival curves (the paper
/// reports 4.4%).
#[derive(Clone, Debug)]
pub struct SurvivalFit {
    pub shape: f64,
    pub scale: f64,
    pub mtbf_h: f64,
    pub median_ttf_h: f64,
    pub rmse: f64,
    /// (t, empirical S(t), fitted S(t))
    pub curve: Vec<(f64, f64, f64)>,
}

pub fn fit_survival(ttfs: &[f64], t_max: f64, points: usize) -> SurvivalFit {
    assert!(ttfs.len() > 10, "need data to fit");
    let (shape, scale) = stats::gamma_fit_moments(ttfs);
    let emp = crate::failure::survival_curve(ttfs, t_max, points);
    let curve: Vec<(f64, f64, f64)> = emp
        .iter()
        .map(|&(t, s)| (t, s, gamma_survival(t, shape, scale)))
        .collect();
    let rmse = stats::rmse(
        &curve.iter().map(|c| c.1).collect::<Vec<_>>(),
        &curve.iter().map(|c| c.2).collect::<Vec<_>>(),
    );
    let mut sorted = ttfs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    SurvivalFit {
        shape,
        scale,
        mtbf_h: stats::mean(ttfs),
        median_ttf_h: sorted[sorted.len() / 2],
        rmse,
        curve,
    }
}

/// Fig. 3b: empirical hazard (failure probability per unit time among
/// survivors) on a time grid.
pub fn hazard_curve(ttfs: &[f64], t_max: f64, points: usize) -> Vec<(f64, f64)> {
    let dt = t_max / points as f64;
    (0..points)
        .map(|i| {
            let lo = i as f64 * dt;
            let hi = lo + dt;
            let at_risk = ttfs.iter().filter(|&&x| x > lo).count() as f64;
            let died = ttfs.iter().filter(|&&x| x > lo && x <= hi).count() as f64;
            let hz = if at_risk > 0.0 { died / (at_risk * dt) } else { 0.0 };
            (lo + 0.5 * dt, hz)
        })
        .collect()
}

/// Failure-rate scaling models for Fig. 13.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailureModel {
    /// MTBF ∝ 1/n (the behaviour observed in production, §3.1)
    LinearMtbf,
    /// each node fails independently with probability p per unit time:
    /// MTBF = 1 / (1 - (1-p)^n)
    IndependentP,
}

/// One point of the Fig. 13 scalability projection.
#[derive(Clone, Debug)]
pub struct ScalePoint {
    pub n_nodes: usize,
    pub full_overhead_frac: f64,
    pub cpr_overhead_frac: f64,
}

/// Project full-recovery vs CPR overhead over a node-count sweep
/// (Eq. 1 vs Eq. 2 with the PLS-chosen interval). `base` holds the
/// per-reference-size constants; `t_fail_at_base` is the MTBF at
/// `base.n_emb_ps` nodes; `p_per_hour` parameterizes the second model.
/// Scaling assumptions (made explicit here; paper §6.6 reaches the same
/// qualitative shape): checkpoints are sharded, so save/load parallelize —
/// O_save, O_load ∝ 1/n at fixed model size. Rescheduling blocks the whole
/// job under full recovery (O_res constant) but is off the critical path
/// under partial recovery — survivors keep training while 1/n of the model
/// waits — so its effective cost also scales 1/n there. This is exactly the
/// paper's argument that "the portion of the updates lost decreases with
/// the number of nodes."
///
/// The job's `n_trainers` (from `base`) rides along at every sweep point:
/// trainers join the failure pool (MTBF scales with N_emb + N_tr total
/// machines) and the PLS-chosen interval carries the trainer share (see
/// `pls::plan`), so Fig. 13 projections reflect trainer count.
pub fn scalability_sweep(
    base: &ClusterConfig,
    target_pls: f64,
    model: FailureModel,
    p_per_hour: f64,
    node_counts: &[usize],
) -> Vec<ScalePoint> {
    let n_tr = base.n_trainers;
    node_counts
        .iter()
        .map(|&n| {
            let t_fail = match model {
                FailureModel::LinearMtbf => {
                    base.t_fail_h * (base.n_emb_ps + n_tr) as f64
                        / (n + n_tr) as f64
                }
                FailureModel::IndependentP => {
                    1.0 / (1.0 - (1.0 - p_per_hour).powi((n + n_tr) as i32))
                }
            };
            let scale = base.n_emb_ps as f64 / n as f64;
            let c_full = ClusterConfig {
                n_emb_ps: n,
                t_fail_h: t_fail,
                o_save_h: base.o_save_h * scale,
                o_load_h: base.o_load_h * scale,
                o_res_h: base.o_res_h, // whole job stalls on full recovery
                ..base.clone()
            };
            let c_part = ClusterConfig {
                o_res_h: base.o_res_h * scale, // off critical path
                ..c_full.clone()
            };
            let full =
                pls::overhead_full_h(&c_full, c_full.t_save_full_h()) / c_full.t_total_h;
            let plan = pls::plan(&c_part, target_pls);
            let cpr = plan.est_overhead_h / c_part.t_total_h;
            ScalePoint { n_nodes: n, full_overhead_frac: full, cpr_overhead_frac: cpr }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::failure::NodeHazard;
    use crate::util::dist;
    use crate::util::rng::Rng;

    #[test]
    fn fit_recovers_synthetic_gamma() {
        let mut rng = Rng::new(1);
        let ttfs: Vec<f64> =
            (0..30_000).map(|_| dist::gamma(&mut rng, 2.0, 14.0)).collect();
        let fit = fit_survival(&ttfs, 120.0, 60);
        assert!((fit.shape - 2.0).abs() < 0.1, "shape {}", fit.shape);
        assert!((fit.scale - 14.0).abs() < 0.7, "scale {}", fit.scale);
        assert!(fit.rmse < 0.01, "rmse {}", fit.rmse);
        assert!((fit.mtbf_h - 28.0).abs() < 1.0);
    }

    #[test]
    fn fleet_fit_matches_paper_quality() {
        // gamma fit of the hazard-model fleet: paper reports RMSE 4.4%;
        // ours must be in single digits too
        let hz = NodeHazard::default();
        let mut rng = Rng::new(2);
        let ttfs = hz.fleet_ttfs(&mut rng, 20_000, 16, 500.0);
        let fit = fit_survival(&ttfs, 150.0, 60);
        assert!(fit.rmse < 0.08, "rmse {}", fit.rmse);
        assert!((8.0..35.0).contains(&fit.mtbf_h), "mtbf {}", fit.mtbf_h);
    }

    #[test]
    fn hazard_is_elevated_early_then_flat() {
        let hz = NodeHazard::default();
        let mut rng = Rng::new(3);
        let ttfs = hz.fleet_ttfs(&mut rng, 30_000, 16, 1e9);
        // fine bins: infant mortality concentrates in the first half-hour
        let hc = hazard_curve(&ttfs, 30.0, 60);
        let early = hc[0].1;
        let later: f64 = hc[20..40].iter().map(|x| x.1).sum::<f64>() / 20.0;
        assert!(early > 3.0 * later,
                "no infant mortality: early {early} vs later {later}");
        // flat tail: adjacent late bins within 3x of each other
        for w in hc[20..50].windows(2) {
            if w[0].1 > 0.0 && w[1].1 > 0.0 {
                let r = w[0].1 / w[1].1;
                assert!((0.33..3.0).contains(&r), "hazard jumps: {r}");
            }
        }
    }

    #[test]
    fn fig13_cpr_scales_better_than_full() {
        let base = crate::config::preset("mini").unwrap().cluster;
        for model in [FailureModel::LinearMtbf, FailureModel::IndependentP] {
            let pts = scalability_sweep(&base, 0.1, model, 0.002,
                                        &[8, 16, 32, 64, 128]);
            // full overhead grows with nodes; CPR stays below full everywhere
            assert!(pts.last().unwrap().full_overhead_frac
                    > pts.first().unwrap().full_overhead_frac,
                    "{model:?}: full not increasing");
            for p in &pts {
                assert!(p.cpr_overhead_frac <= p.full_overhead_frac + 1e-9,
                        "{model:?}: CPR worse at n={}", p.n_nodes);
            }
            // paper: CPR overhead *decreases* with more nodes
            assert!(pts.last().unwrap().cpr_overhead_frac
                    <= pts.first().unwrap().cpr_overhead_frac + 1e-9,
                    "{model:?}: CPR not improving with scale");
        }
    }
}
