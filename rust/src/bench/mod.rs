//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` targets use `harness = false` binaries built on this:
//! warmup, fixed-duration sampling, mean/p50/p99 reporting, and optional
//! throughput. Output is one aligned line per benchmark plus an optional
//! machine-readable JSON dump.

use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::util::json::{arr, num, obj, s, Json, JsonWriter};
use crate::util::stats;

/// Every [`Bench::run`] (and [`record_external`]) registers its result
/// here so a bench binary can dump one machine-readable file at exit via
/// [`write_json`] — the CI bench artifact the acceptance numbers (e.g.
/// the `scatter_contention` sharded-vs-global rows) are read from.
static REGISTRY: Mutex<Vec<JsonRow>> = Mutex::new(Vec::new());

struct JsonRow {
    name: String,
    mean_s: f64,
    p50_s: f64,
    p99_s: f64,
    samples: usize,
    throughput_per_s: Option<f64>,
}

fn register(r: &BenchResult) {
    REGISTRY.lock().unwrap().push(JsonRow {
        name: r.name.clone(),
        mean_s: r.mean_s(),
        p50_s: r.p50_s(),
        p99_s: r.p99_s(),
        samples: r.samples.len(),
        throughput_per_s: r.throughput(),
    });
}

/// Record an externally-timed measurement (e.g. a multi-threaded
/// contention run the closure-based harness cannot express): one sample
/// of `total_secs`, with throughput = `elements / total_secs`. Prints the
/// standard report line and registers the row for [`write_json`].
pub fn record_external(name: &str, total_secs: f64, elements: u64) -> BenchResult {
    let r = BenchResult {
        name: name.to_string(),
        samples: vec![total_secs],
        elements: Some(elements),
    };
    println!("{}", r.report_line());
    register(&r);
    r
}

/// Dump every benchmark recorded so far to `path` as JSON.
pub fn write_json(path: &str) -> std::io::Result<()> {
    let rows = REGISTRY.lock().unwrap();
    let results: Vec<Json> = rows
        .iter()
        .map(|r| {
            obj(vec![
                ("name", s(&r.name)),
                ("mean_s", num(r.mean_s)),
                ("p50_s", num(r.p50_s)),
                ("p99_s", num(r.p99_s)),
                ("samples", num(r.samples as f64)),
                ("throughput_per_s",
                 r.throughput_per_s.map_or(Json::Null, num)),
            ])
        })
        .collect();
    let doc = obj(vec![("results", arr(results))]);
    std::fs::write(path, JsonWriter::write(&doc))
}

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub samples: Vec<f64>, // seconds per iteration
    pub elements: Option<u64>,
}

impl BenchResult {
    pub fn mean_s(&self) -> f64 {
        stats::mean(&self.samples)
    }

    pub fn p50_s(&self) -> f64 {
        stats::percentile(&self.samples, 50.0)
    }

    pub fn p99_s(&self) -> f64 {
        stats::percentile(&self.samples, 99.0)
    }

    /// elements/second, if an element count was attached.
    pub fn throughput(&self) -> Option<f64> {
        self.elements.map(|e| e as f64 / self.mean_s())
    }

    pub fn report_line(&self) -> String {
        let tp = match self.throughput() {
            Some(t) => format!("  {:>12}/s", human(t)),
            None => String::new(),
        };
        format!(
            "{:<44} mean {:>12}  p50 {:>12}  p99 {:>12}  ({} samples){tp}",
            self.name,
            human_time(self.mean_s()),
            human_time(self.p50_s()),
            human_time(self.p99_s()),
            self.samples.len(),
        )
    }
}

fn human_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

fn human(x: f64) -> String {
    if x >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.2}k", x / 1e3)
    } else {
        format!("{x:.1}")
    }
}

/// Benchmark builder.
pub struct Bench {
    name: String,
    warmup: Duration,
    measure: Duration,
    max_samples: usize,
    elements: Option<u64>,
}

impl Bench {
    pub fn new(name: &str) -> Self {
        Self {
            name: name.to_string(),
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(900),
            max_samples: 2_000,
            elements: None,
        }
    }

    /// Attach an element count for throughput reporting.
    pub fn throughput(mut self, elements: u64) -> Self {
        self.elements = Some(elements);
        self
    }

    pub fn warmup_ms(mut self, ms: u64) -> Self {
        self.warmup = Duration::from_millis(ms);
        self
    }

    pub fn measure_ms(mut self, ms: u64) -> Self {
        self.measure = Duration::from_millis(ms);
        self
    }

    /// Run `f` repeatedly; returns timing stats. `f`'s return value is
    /// black-boxed to stop the optimizer from deleting the work.
    pub fn run<T, F: FnMut() -> T>(self, mut f: F) -> BenchResult {
        let wstart = Instant::now();
        while wstart.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::new();
        let mstart = Instant::now();
        while mstart.elapsed() < self.measure && samples.len() < self.max_samples {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        let r = BenchResult { name: self.name, samples, elements: self.elements };
        println!("{}", r.report_line());
        register(&r);
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let r = Bench::new("noop")
            .warmup_ms(5)
            .measure_ms(20)
            .run(|| std::hint::black_box(1 + 1));
        assert!(!r.samples.is_empty());
        assert!(r.mean_s() >= 0.0);
        assert!(r.p99_s() >= r.p50_s());
    }

    #[test]
    fn throughput_attached() {
        let r = Bench::new("tp")
            .warmup_ms(1)
            .measure_ms(5)
            .throughput(1000)
            .run(|| std::hint::black_box((0..100).sum::<u64>()));
        assert!(r.throughput().unwrap() > 0.0);
    }

    #[test]
    fn json_dump_roundtrips() {
        Bench::new("json_dump_probe")
            .warmup_ms(1)
            .measure_ms(5)
            .throughput(10)
            .run(|| std::hint::black_box(2 * 2));
        record_external("json_dump_external", 0.5, 100);
        let path = std::env::temp_dir().join("cpr_bench_dump.json");
        write_json(path.to_str().unwrap()).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let results = doc.get("results").unwrap().as_arr().unwrap();
        let names: Vec<&str> = results
            .iter()
            .map(|r| r.get("name").unwrap().as_str().unwrap())
            .collect();
        assert!(names.contains(&"json_dump_probe"));
        assert!(names.contains(&"json_dump_external"));
        let ext = results
            .iter()
            .find(|r| r.get("name").unwrap().as_str().unwrap() == "json_dump_external")
            .unwrap();
        assert_eq!(ext.get("throughput_per_s").unwrap().as_f64().unwrap(), 200.0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn human_time_units() {
        assert!(human_time(2e-9).contains("ns"));
        assert!(human_time(2e-6).contains("µs"));
        assert!(human_time(2e-3).contains("ms"));
        assert!(human_time(2.0).contains(" s"));
    }
}
