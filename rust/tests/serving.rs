//! Serving-plane acceptance tests (ISSUE 8).
//!
//! Two properties anchor the serving plane:
//!
//! * **Torn-read safety** — `serve_gather` never returns a row that mixes
//!   two published states. The property test hammers one node with
//!   concurrent serving reads while writer threads overwrite the node
//!   with sentinel patterns (every float of one publication is the same
//!   value), so any torn read is detectable as a non-uniform row.
//!   Exercised on both backends: the in-proc seqlock path (where tearing
//!   is a real hazard the sequence check must catch) and the threaded
//!   snapshot path (where it holds by construction).
//! * **Training neutrality** — the load generator is strictly read-only:
//!   the same job run with serving off and on must produce an IDENTICAL
//!   `TrainReport` (AUC, logloss, PLS, ledger, loss curve), failures
//!   included, on both backends.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use cpr::cluster::{PsControlPlane, PsDataPlane, PsServePlane, ServeError};
use cpr::config::{preset, JobConfig, PsBackendKind, Strategy};
use cpr::coordinator::{run_training, RunOptions, TrainReport};
use cpr::embedding::{PsCluster, TableInfo};
use cpr::failure::{uniform_schedule, FailureEvent};
use cpr::runtime::{ModelExe, Runtime};
use cpr::util::rng::Rng;

/// Serialize the heavy tests in this binary (each spawns its own thread
/// pools; overlapping them just adds CI timing noise).
static TEST_LOCK: Mutex<()> = Mutex::new(());

// ---------------------------------------------------------------------------
// torn-read safety (satellite: property test)
// ---------------------------------------------------------------------------

const ROWS: usize = 64;
const DIM: usize = 8;
const N_NODES: usize = 2;
const TARGET: usize = 1; // the hammered node
const WRITERS: usize = 2;
const WRITES_PER_WRITER: usize = 300;

/// Sentinel for writer `w`'s `i`-th publication: every float of the node
/// is this one value, so a read mixing two publications cannot be
/// row-uniform.
fn sentinel(w: usize, i: usize) -> f32 {
    (w * 10_000 + i + 1) as f32
}

fn sentinel_state() -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
    let local_rows = ROWS / N_NODES;
    (vec![vec![0.0; local_rows * DIM]], vec![vec![0.0; local_rows]])
}

/// Hammer `TARGET` with sentinel-publishing writers and concurrent
/// serving readers; every returned row must be uniform (untorn) and, once
/// the first sentinel is published, a known sentinel value.
fn hammer<C>(cluster: Arc<C>, tag: &str)
where
    C: PsControlPlane + PsServePlane + 'static,
{
    // publish an initial sentinel so readers never see the (non-uniform)
    // deterministic init values
    let (mut shards, opt) = sentinel_state();
    shards[0].fill(sentinel(0, 0));
    cluster.load_node(TARGET, &shards, &opt);
    cluster.publish_serve_view();

    let done = Arc::new(AtomicBool::new(false));
    let writers: Vec<_> = (0..WRITERS)
        .map(|w| {
            let cluster = Arc::clone(&cluster);
            std::thread::spawn(move || {
                let (mut shards, opt) = sentinel_state();
                for i in 0..WRITES_PER_WRITER {
                    shards[0].fill(sentinel(w, i));
                    cluster.load_node(TARGET, &shards, &opt);
                    if i % 16 == 0 {
                        cluster.publish_serve_view();
                    }
                }
            })
        })
        .collect();
    let readers: Vec<_> = (0..2)
        .map(|r| {
            let cluster = Arc::clone(&cluster);
            let done = Arc::clone(&done);
            let tag = tag.to_string();
            std::thread::spawn(move || {
                let mut rng = Rng::new(0xC0FFEE ^ r as u64);
                let mut out = vec![0.0f32; DIM];
                let mut reads = 0u64;
                while !done.load(Ordering::Acquire) {
                    // any global row owned by TARGET under r % n routing
                    let local = rng.next_u64() as usize % (ROWS / N_NODES);
                    let row = (local * N_NODES + TARGET) as u32;
                    cluster
                        .serve_gather(&[row], &mut out)
                        .expect("no node dies in this test");
                    let first = out[0];
                    assert!(
                        out.iter().all(|&v| v == first),
                        "{tag}: torn read on row {row}: {out:?}"
                    );
                    // uniform AND a value some writer actually published
                    let s = first as usize;
                    assert!(
                        s >= 1 && s <= WRITERS * 10_000 + WRITES_PER_WRITER,
                        "{tag}: row {row} holds non-sentinel value {first}"
                    );
                    reads += 1;
                }
                reads
            })
        })
        .collect();
    for w in writers {
        w.join().expect("writer panicked");
    }
    // let the readers observe the final published state too
    cluster.publish_serve_view();
    std::thread::sleep(std::time::Duration::from_millis(20));
    done.store(true, Ordering::Release);
    for r in readers {
        let reads = r.join().expect("reader panicked (torn read?)");
        assert!(reads > 0, "{tag}: reader never completed a read");
    }
}

#[test]
fn serve_reads_are_never_torn_inproc() {
    let _g = TEST_LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    let tables = vec![TableInfo { rows: ROWS, dim: DIM }];
    hammer(Arc::new(PsCluster::new(tables, N_NODES, 5)), "inproc");
}

#[test]
fn serve_reads_are_never_torn_threaded() {
    let _g = TEST_LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    let tables = vec![TableInfo { rows: ROWS, dim: DIM }];
    hammer(
        Arc::new(cpr::cluster::ThreadedCluster::new(tables, N_NODES, 5)),
        "threaded",
    );
}

// ---------------------------------------------------------------------------
// poison path: a writer that panics mid-update must read as NodeDown
// ---------------------------------------------------------------------------

/// Drive one writer panic on `TARGET` and assert the serving plane
/// converts it into `NodeDown` within its bounded spin budget, on
/// whichever backend `cluster` is.
///
/// In-proc: the panic unwinds with the node write guard held and the
/// seqlock epoch open — guard `Drop` poisons→kills the node, and the
/// permanently-odd sequence pushes readers onto the dead-poll path.
/// Threaded: the panic unwinds the worker thread itself; the spawn
/// wrapper raises the node's crash flag, which serving checks before
/// trusting the (stale) published view.
fn writer_panic_yields_node_down<C>(cluster: &C, tag: &str)
where
    C: PsDataPlane + PsControlPlane + PsServePlane + Sync,
{
    // row 100_001 routes to node 1 (odd) at local 50_000 — far outside
    // the 32-row shard, so the apply panics after the write began
    assert_eq!(100_001 % N_NODES, TARGET);
    let crashed = std::thread::scope(|s| {
        s.spawn(|| {
            cluster.apply_grads(
                &[100_001u32],
                1,
                &[0.0f32; DIM],
                1.0,
                cpr::embedding::EmbOptimizer::Sgd,
            )
        })
        .join()
    });
    assert!(crashed.is_err(), "{tag}: the poisoned apply must panic");
    // the in-proc backend converts poison synchronously (guard Drop ran
    // before join returned); the threaded worker raises its crash flag as
    // the unwind escapes its loop, which can trail the router's own
    // panic — bound the lag instead of assuming either ordering
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
    while PsControlPlane::alive(cluster, TARGET) {
        assert!(
            std::time::Instant::now() < deadline,
            "{tag}: writer panic never marked node {TARGET} dead"
        );
        std::thread::yield_now();
    }
    // victim reads fail fast (bounded spin, not a hang, never torn state)
    let row = (N_NODES + TARGET) as u32; // in-range row owned by TARGET
    let mut out = vec![0.0f32; DIM];
    assert_eq!(
        cluster.serve_gather(&[row], &mut out),
        Err(ServeError::NodeDown { node: TARGET }),
        "{tag}: victim must serve NodeDown"
    );
    // survivors are unaffected
    cluster
        .serve_gather(&[0u32], &mut out)
        .unwrap_or_else(|e| panic!("{tag}: survivor refused to serve: {e:?}"));
    // the standard recovery protocol restores service
    cluster.kill_node(TARGET);
    cluster.respawn_node(TARGET);
    cluster
        .serve_gather(&[row], &mut out)
        .unwrap_or_else(|e| panic!("{tag}: respawned node refused to serve: {e:?}"));
}

#[test]
fn writer_panic_serves_node_down_inproc() {
    let _g = TEST_LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    let tables = vec![TableInfo { rows: ROWS, dim: DIM }];
    writer_panic_yields_node_down(&PsCluster::new(tables, N_NODES, 5), "inproc");
}

#[test]
fn writer_panic_serves_node_down_threaded() {
    let _g = TEST_LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    let tables = vec![TableInfo { rows: ROWS, dim: DIM }];
    writer_panic_yields_node_down(
        &cpr::cluster::ThreadedCluster::new(tables, N_NODES, 5),
        "threaded",
    );
}

// ---------------------------------------------------------------------------
// training neutrality: serving on vs off
// ---------------------------------------------------------------------------

fn load_model(preset_name: &str) -> ModelExe {
    Runtime::cpu()
        .expect("runtime")
        .load_model("artifacts", preset_name)
        .expect("loading model")
}

thread_local! {
    static MINI: std::cell::OnceCell<ModelExe> = const { std::cell::OnceCell::new() };
}

fn with_mini<R>(f: impl FnOnce(&ModelExe) -> R) -> R {
    MINI.with(|cell| f(cell.get_or_init(|| load_model("mini"))))
}

fn test_cfg(strategy: Strategy) -> JobConfig {
    let mut cfg = preset("mini").unwrap();
    cfg.data.train_samples = 38_400; // 300 steps
    cfg.data.eval_samples = 12_800;
    cfg.checkpoint.strategy = strategy;
    cfg
}

fn sched(seed: u64, n: usize, victims: usize, t_total: f64, n_nodes: usize)
         -> Vec<FailureEvent> {
    let mut rng = Rng::new(seed);
    uniform_schedule(&mut rng, n, t_total, n_nodes, victims)
}

fn run(cfg: &JobConfig, schedule: Vec<FailureEvent>) -> TrainReport {
    with_mini(|model| {
        run_training(model, cfg, &RunOptions { schedule, ..Default::default() })
    })
    .expect("training run")
}

fn assert_reports_identical(off: &TrainReport, on: &TrainReport, tag: &str) {
    assert_eq!(off.final_auc, on.final_auc, "{tag}: AUC diverged");
    assert_eq!(off.final_logloss, on.final_logloss, "{tag}: logloss diverged");
    assert_eq!(off.pls, on.pls, "{tag}: PLS diverged");
    assert_eq!(off.steps_executed, on.steps_executed, "{tag}: steps diverged");
    assert_eq!(off.failures_seen, on.failures_seen, "{tag}");
    assert_eq!(off.ledger, on.ledger, "{tag}: overhead ledger diverged");
    assert_eq!(off.train_loss.points, on.train_loss.points,
               "{tag}: loss curve diverged");
}

#[test]
fn serving_is_bit_neutral_on_both_backends() {
    let _g = TEST_LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    for backend in [PsBackendKind::InProc, PsBackendKind::Threaded] {
        let mut cfg = test_cfg(Strategy::CprMfu);
        cfg.cluster.backend = backend;
        let n = cfg.cluster.n_emb_ps;
        let schedule = sched(23, 3, 2, cfg.cluster.t_total_h, n);

        let off = run(&cfg, schedule.clone());
        assert!(off.serving.is_none(), "serving report without serving?");
        cfg.serving.enabled = true;
        cfg.serving.qps = 50_000.0;
        cfg.serving.clients = 2;
        let on = run(&cfg, schedule);

        assert_eq!(off.failures_seen, 3);
        assert_reports_identical(&off, &on, backend.name());
        let serve = on.serving.expect("serving report missing");
        assert!(serve.total_requests > 0,
                "{}: load generator issued no requests", backend.name());
        let steady = serve.regime("steady").expect("steady regime row");
        assert!(steady.requests > 0, "{}: no steady traffic", backend.name());
    }
}
