//! Golden equivalence for the checkpoint-policy engine (ISSUE 4).
//!
//! The coordinator's inlined strategy `match` was replaced by boxed
//! policy objects (`cpr::policy`). These tests pin the refactor:
//!
//! * for EVERY pre-existing strategy, an N = 1 run driven through the
//!   policy objects is bit-identical — final AUC, logloss, PLS, loss
//!   curve, overhead ledger — to the pre-refactor coordinator
//!   (preserved verbatim as `coordinator::reference`), on both cluster
//!   backends, under a failure schedule;
//! * at N = 4 with mixed PS + trainer failures, every strategy
//!   (including the new `cpr-adaptive`) is bit-identical ACROSS the two
//!   backends;
//! * `cpr-adaptive` runs end-to-end and its online re-planned intervals
//!   land in the `TrainReport` ledger, widening on quiet jobs and
//!   narrowing under failure storms.

use cpr::config::{preset, JobConfig, PsBackendKind, Strategy};
use cpr::coordinator::reference::run_training_reference;
use cpr::coordinator::{run_training, RunOptions, TrainReport};
use cpr::failure::{uniform_schedule, FailureEvent};
use cpr::pls;
use cpr::runtime::{ModelExe, Runtime};
use cpr::util::rng::Rng;

/// The strategies that existed before the policy engine — the set the
/// reference loop is an executable specification for.
const PRE_EXISTING: [Strategy; 6] = [
    Strategy::Full,
    Strategy::PartialNaive,
    Strategy::CprVanilla,
    Strategy::CprScar,
    Strategy::CprMfu,
    Strategy::CprSsu,
];

fn load_model() -> ModelExe {
    Runtime::cpu()
        .expect("runtime")
        .load_model("artifacts", "mini")
        .expect("loading model")
}

/// 100-global-step mini job (fast enough for the strategy × backend grid).
fn grid_cfg(strategy: Strategy, backend: PsBackendKind, n_trainers: usize) -> JobConfig {
    let mut cfg = preset("mini").unwrap();
    cfg.data.train_samples = 128 * n_trainers * 100;
    cfg.data.eval_samples = 3_840;
    cfg.checkpoint.strategy = strategy;
    cfg.cluster.backend = backend;
    cfg.cluster.n_trainers = n_trainers;
    cfg
}

fn ps_only_schedule(seed: u64, n: usize, victims: usize, cfg: &JobConfig) -> Vec<FailureEvent> {
    let mut rng = Rng::new(seed);
    uniform_schedule(&mut rng, n, cfg.cluster.t_total_h, cfg.cluster.n_emb_ps, victims)
}

fn assert_bit_identical(a: &TrainReport, b: &TrainReport, what: &str) {
    assert_eq!(a.final_auc, b.final_auc, "{what}: AUC diverged");
    assert_eq!(a.final_logloss, b.final_logloss, "{what}: logloss diverged");
    assert_eq!(a.pls, b.pls, "{what}: PLS diverged");
    assert_eq!(a.steps_executed, b.steps_executed, "{what}: steps diverged");
    assert_eq!(a.failures_seen, b.failures_seen, "{what}: failure count diverged");
    assert_eq!(a.ledger, b.ledger, "{what}: overhead ledger diverged");
    assert_eq!(a.train_loss.points, b.train_loss.points,
               "{what}: loss curve diverged");
}

fn n1_matches_reference_on(backend: PsBackendKind) {
    let model = load_model();
    for strategy in PRE_EXISTING {
        let cfg = grid_cfg(strategy.clone(), backend, 1);
        let schedule = ps_only_schedule(17, 3, 2, &cfg);
        let opts = RunOptions { schedule, ..Default::default() };
        let a = run_training(&model, &cfg, &opts).expect("policy-driven run");
        let b = run_training_reference(&model, &cfg, &opts).expect("reference run");
        let what = format!("{}/{}", backend.name(), strategy.name());
        assert_eq!(a.strategy, strategy.name(), "{what}");
        assert_eq!(a.backend, b.backend, "{what}");
        assert_bit_identical(&a, &b, &what);
    }
}

#[test]
fn n1_policy_driver_matches_reference_for_every_strategy_inproc() {
    n1_matches_reference_on(PsBackendKind::InProc);
}

#[test]
fn n1_policy_driver_matches_reference_for_every_strategy_threaded() {
    n1_matches_reference_on(PsBackendKind::Threaded);
}

#[test]
fn n4_mixed_failures_backend_identical_for_every_strategy() {
    let model = load_model();
    // one trainer loss + one PS loss, fixed times (the ISSUE-3 scenario,
    // now swept over the whole registry including cpr-adaptive)
    let schedule = vec![
        FailureEvent { time_h: 20.0, victims: vec![], trainer_victims: vec![2] },
        FailureEvent { time_h: 35.0, victims: vec![3], trainer_victims: vec![] },
    ];
    let mut all = PRE_EXISTING.to_vec();
    all.push(Strategy::CprAdaptive);
    for strategy in all {
        let mut per_backend = Vec::new();
        for backend in [PsBackendKind::InProc, PsBackendKind::Threaded] {
            let mut cfg = grid_cfg(strategy.clone(), backend, 4);
            // tighter target so CPR (incl. adaptive) saves several times
            cfg.checkpoint.target_pls = 0.02;
            let opts = RunOptions { schedule: schedule.clone(), ..Default::default() };
            let r = run_training(&model, &cfg, &opts).expect("N=4 run");
            assert_eq!(r.n_trainers, 4, "{}", strategy.name());
            assert_eq!(r.failures_seen, 2, "{}", strategy.name());
            assert!(r.final_auc.is_finite() && r.final_auc > 0.5, "{}: AUC {}",
                    strategy.name(), r.final_auc);
            per_backend.push(r);
        }
        let what = format!("N=4/{}", strategy.name());
        assert_bit_identical(&per_backend[0], &per_backend[1], &what);
    }
}

#[test]
fn adaptive_widens_its_interval_on_a_quiet_job() {
    let model = load_model();
    let mut cfg = grid_cfg(Strategy::CprAdaptive, PsBackendKind::InProc, 1);
    cfg.checkpoint.target_pls = 0.02; // plan ≈ 10 h → several majors in 56 h
    let r = run_training(&model, &cfg, &RunOptions::default()).unwrap();
    assert!(!r.fell_back);
    assert_eq!(r.pls, 0.0);
    assert!(!r.ledger.replans.is_empty(),
            "a quiet job must still re-plan (the MTBF estimate rises)");
    let p0 = pls::plan(&cfg.cluster, cfg.checkpoint.target_pls);
    let mut prev = p0.t_save_h;
    for &(at_h, t_save_h) in &r.ledger.replans {
        assert!(at_h.is_finite() && t_save_h.is_finite());
        assert!(t_save_h > prev,
                "no observed failures → every re-plan must widen: \
                 {t_save_h} !> {prev} at {at_h} h");
        prev = t_save_h;
    }
}

#[test]
fn adaptive_narrows_its_interval_under_a_failure_storm() {
    let model = load_model();
    let mut cfg = grid_cfg(Strategy::CprAdaptive, PsBackendKind::InProc, 1);
    cfg.checkpoint.target_pls = 0.02;
    let schedule = ps_only_schedule(23, 8, 1, &cfg); // 4× the planned rate
    let r = run_training(&model, &cfg, &RunOptions { schedule, ..Default::default() })
        .unwrap();
    assert_eq!(r.strategy, "cpr-adaptive");
    assert!(!r.fell_back);
    assert_eq!(r.failures_seen, 8);
    assert!(r.pls > 0.0, "PS losses under partial recovery accrue PLS");
    assert_eq!(r.ledger.lost_h, 0.0, "partial recovery never rewinds");
    assert!(!r.ledger.replans.is_empty());
    let p0 = pls::plan(&cfg.cluster, cfg.checkpoint.target_pls);
    let last = r.ledger.replans.last().unwrap().1;
    assert!(last < p0.t_save_h,
            "a failure storm must narrow the interval: {last} !< {}",
            p0.t_save_h);
}
