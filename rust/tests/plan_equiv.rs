//! Route-once batch plans (ISSUE 10): planned ≡ unplanned, and the
//! zero-allocation contract.
//!
//! * planned gather / planned per-node applies are **bit-identical** to
//!   the unplanned pooled paths on random Zipf batches — hotness 1 and 4,
//!   cross-table duplicate rows, both optimizers, dead-node edges — on
//!   BOTH cluster backends;
//! * a full `cpr-mfu` training run with PS failures through the planned
//!   driver is bit-identical (AUC, logloss, PLS, ledger, loss curve) to
//!   the unplanned reference loop;
//! * the steady-state planned step on the in-proc backend performs ZERO
//!   heap allocations after warmup, counted by the real global allocator
//!   ([`cpr::testing::alloc::CountingAlloc`], installed below); the
//!   threaded backend's caller-side allocations stay under a documented
//!   budget (mpsc queue blocks are the only remaining source).

use cpr::cluster::{PlanArena, PsDataPlane, ThreadedCluster};
use cpr::config::{preset, JobConfig, PsBackendKind, Strategy};
use cpr::coordinator::reference::run_training_reference;
use cpr::coordinator::{run_training, RunOptions};
use cpr::embedding::{EmbOptimizer, PsCluster, TableInfo};
use cpr::failure::{uniform_schedule, FailureEvent};
use cpr::prop_assert;
use cpr::testing::alloc;
use cpr::testing::{forall, gen};
use cpr::util::dist::Zipf;
use cpr::util::rng::Rng;

// The audit only counts in a binary that installs the wrapper; this is
// the binary the zero-alloc contract is asserted in.
#[global_allocator]
static ALLOC: alloc::CountingAlloc = alloc::CountingAlloc;

// ---------------------------------------------------------------------------
// planned ≡ unplanned property (both backends)
// ---------------------------------------------------------------------------

/// Drive one random batch through twin clusters — unplanned on `a`,
/// planned on `b` — and require bit-identical gather output and
/// bit-identical post-apply table/optimizer state.
fn planned_matches_unplanned<B, F>(make: F, root_seed: u64)
where
    B: cpr::cluster::PsBackend,
    F: Fn(Vec<TableInfo>, usize, u64) -> B,
{
    forall(root_seed, 10, |rng| {
        let n_nodes = gen::usize_in(rng, 2, 5);
        let dim = 4;
        let rows0 = gen::usize_in(rng, 30, 150);
        let rows1 = gen::usize_in(rng, 20, 80);
        let tables =
            vec![TableInfo { rows: rows0, dim }, TableInfo { rows: rows1, dim }];
        for &hotness in &[1usize, 4] {
            let batch = gen::usize_in(rng, 2, 16);
            let n_slots = batch * 2 * hotness;
            // Zipfian rows: both tables sample the same small ranks, so
            // cross-table duplicate row ids occur constantly — the plan
            // must keep them distinct (table is part of the dedup key).
            let s = gen::f64_in(rng, 0.8, 1.5);
            let z0 = Zipf::new(rows0, s);
            let z1 = Zipf::new(rows1, s);
            let indices: Vec<u32> = (0..n_slots)
                .map(|slot| {
                    let t = (slot / hotness) % 2;
                    (if t == 0 { z0.sample(rng) } else { z1.sample(rng) }) as u32
                })
                .collect();
            let cseed = rng.next_u64();
            let a = make(tables.clone(), n_nodes, cseed);
            let b = make(tables.clone(), n_nodes, cseed);

            // gather: planned output must be bit-identical
            let mut out_a = vec![0.0f32; batch * 2 * dim];
            let mut out_b = vec![0.0f32; batch * 2 * dim];
            a.gather_pooled(&indices, hotness, &mut out_a);
            let mut arena = PlanArena::new();
            arena.build(&indices, hotness, 2, n_nodes);
            let (plan, scratch) = arena.parts_mut();
            b.gather_planned(plan, scratch, &mut out_b);
            prop_assert!(out_a == out_b,
                         "gather diverged (hotness {hotness}, B {batch}, n {n_nodes})");

            // apply: full scan vs plan-driven per-node slot lists
            let grads = gen::f32_vec(rng, batch * 2 * dim);
            let opt = if rng.f64() < 0.5 {
                EmbOptimizer::Sgd
            } else {
                EmbOptimizer::RowAdagrad { eps: 1e-8 }
            };
            a.apply_grads(&indices, hotness, &grads, 0.3, opt);
            for node in 0..n_nodes {
                if plan.touched().get(node) {
                    b.apply_grads_planned_node(node, plan, scratch, &grads, 0.3, opt);
                }
            }
            for t in 0..2 {
                let ids: Vec<u32> = (0..tables[t].rows as u32).collect();
                let (va, oa) = a.read_rows(t, &ids);
                let (vb, ob) = b.read_rows(t, &ids);
                prop_assert!(va == vb, "table {t} weights diverged after apply");
                prop_assert!(oa == ob, "table {t} optimizer state diverged");
            }
        }
        Ok(())
    });
}

#[test]
fn planned_matches_unplanned_inproc() {
    planned_matches_unplanned(PsCluster::new, 0xA1);
}

#[test]
fn planned_matches_unplanned_threaded() {
    planned_matches_unplanned(ThreadedCluster::new, 0xA2);
}

/// Dead-node edge: with one node killed and every batch row routed away
/// from it, planned gather/apply must behave exactly like the unplanned
/// paths (which skip untouched nodes, dead or not).
fn planned_skips_dead_nodes<B, F>(make: F, root_seed: u64)
where
    B: cpr::cluster::PsBackend,
    F: Fn(Vec<TableInfo>, usize, u64) -> B,
{
    forall(root_seed, 8, |rng| {
        let n_nodes = gen::usize_in(rng, 2, 4);
        let dead = rng.usize_below(n_nodes);
        let dim = 4;
        let rows = gen::usize_in(rng, 40, 120);
        let tables = vec![TableInfo { rows, dim }];
        let hotness = gen::usize_in(rng, 1, 3);
        let batch = gen::usize_in(rng, 2, 8);
        let n_slots = batch * hotness;
        let indices: Vec<u32> = (0..n_slots)
            .map(|_| loop {
                let r = rng.usize_below(rows);
                if r % n_nodes != dead {
                    break r as u32;
                }
            })
            .collect();
        let cseed = rng.next_u64();
        let a = make(tables.clone(), n_nodes, cseed);
        let b = make(tables.clone(), n_nodes, cseed);
        a.kill_node(dead);
        b.kill_node(dead);

        let mut out_a = vec![0.0f32; batch * dim];
        let mut out_b = vec![0.0f32; batch * dim];
        a.gather_pooled(&indices, hotness, &mut out_a);
        let mut arena = PlanArena::new();
        arena.build(&indices, hotness, 1, n_nodes);
        let (plan, scratch) = arena.parts_mut();
        prop_assert!(!plan.touched().get(dead), "plan must not touch the dead node");
        b.gather_planned(plan, scratch, &mut out_b);
        prop_assert!(out_a == out_b, "gather diverged with node {dead} dead");

        let grads = gen::f32_vec(rng, batch * dim);
        a.apply_grads(&indices, hotness, &grads, 0.5, EmbOptimizer::Sgd);
        for node in 0..n_nodes {
            if plan.touched().get(node) {
                b.apply_grads_planned_node(node, plan, scratch, &grads, 0.5,
                                           EmbOptimizer::Sgd);
            }
        }
        let ids: Vec<u32> = indices.clone();
        let (va, _) = a.read_rows(0, &ids);
        let (vb, _) = b.read_rows(0, &ids);
        prop_assert!(va == vb, "applied rows diverged with node {dead} dead");
        Ok(())
    });
}

#[test]
fn planned_skips_dead_nodes_inproc() {
    planned_skips_dead_nodes(PsCluster::new, 0xB1);
}

#[test]
fn planned_skips_dead_nodes_threaded() {
    planned_skips_dead_nodes(ThreadedCluster::new, 0xB2);
}

// ---------------------------------------------------------------------------
// end-to-end golden: planned driver ≡ unplanned reference
// ---------------------------------------------------------------------------

/// The policy_golden cpr-mfu-with-failures scenario, now exercising the
/// fully planned step path (plan-shared gather, turnstile applies, MFU
/// weighted recording, delta capture): bit-identical to the preserved
/// unplanned reference loop, and the report's dedup counters account for
/// every training gather slot.
#[test]
fn planned_cpr_mfu_failure_run_matches_reference() {
    let model = cpr::runtime::Runtime::cpu()
        .expect("runtime")
        .load_model("artifacts", "mini")
        .expect("loading model");
    for backend in [PsBackendKind::InProc, PsBackendKind::Threaded] {
        let mut cfg: JobConfig = preset("mini").unwrap();
        cfg.data.train_samples = 128 * 100;
        cfg.data.eval_samples = 3_840;
        cfg.checkpoint.strategy = Strategy::CprMfu;
        cfg.cluster.backend = backend;
        cfg.cluster.n_trainers = 1;
        let schedule: Vec<FailureEvent> = {
            let mut rng = Rng::new(17);
            uniform_schedule(&mut rng, 3, cfg.cluster.t_total_h,
                             cfg.cluster.n_emb_ps, 2)
        };
        let opts = RunOptions { schedule, ..Default::default() };
        let a = run_training(&model, &cfg, &opts).expect("planned run");
        let b = run_training_reference(&model, &cfg, &opts).expect("reference run");
        let what = format!("cpr-mfu/{}", backend.name());
        assert_eq!(a.final_auc, b.final_auc, "{what}: AUC diverged");
        assert_eq!(a.final_logloss, b.final_logloss, "{what}: logloss diverged");
        assert_eq!(a.pls, b.pls, "{what}: PLS diverged");
        assert_eq!(a.steps_executed, b.steps_executed, "{what}: steps diverged");
        assert_eq!(a.ledger, b.ledger, "{what}: ledger diverged");
        assert_eq!(a.train_loss.points, b.train_loss.points,
                   "{what}: loss curve diverged");
        // dedup accounting: every planned training gather's slots are
        // split exactly into uniques + hits; the reference never plans
        let slots_per_step =
            (cfg.model.batch * cfg.model.num_sparse * cfg.data.hotness) as u64;
        assert_eq!(a.ps_stats.unique_rows + a.ps_stats.dedup_hits,
                   a.steps_executed * slots_per_step,
                   "{what}: dedup counters must cover every training slot");
        assert!(a.ps_stats.dedup_hits > 0,
                "{what}: a Zipfian batch must contain duplicate rows");
        assert_eq!(b.ps_stats.unique_rows, 0, "{what}: reference must not plan");
    }
}

// ---------------------------------------------------------------------------
// the zero-allocation contract
// ---------------------------------------------------------------------------

/// One planned data-plane step: plan build, planned gather, per-node
/// planned applies, and planned access recording into a preallocated
/// counter table. Exactly the per-step work the trainer + coordinator hot
/// path performs against the cluster (the trainer's reply channel and
/// model math are outside the data-plane contract).
#[allow(clippy::too_many_arguments)]
fn planned_step<B: PsDataPlane>(
    cluster: &B,
    arena: &mut PlanArena,
    indices: &[u32],
    hotness: usize,
    num_tables: usize,
    n_nodes: usize,
    grads: &[f32],
    out: &mut [f32],
    counts: &mut [u64],
    rows_per_table: usize,
) {
    arena.build(indices, hotness, num_tables, n_nodes);
    let (plan, scratch) = arena.parts_mut();
    cluster.gather_planned(plan, scratch, out);
    for node in 0..n_nodes {
        if plan.touched().get(node) {
            cluster.apply_grads_planned_node(node, plan, scratch, grads, 0.05,
                                             EmbOptimizer::Sgd);
        }
    }
    for u in 0..plan.n_unique() {
        let a = plan.access(u);
        counts[a.table as usize * rows_per_table + a.row as usize] += a.count as u64;
    }
}

/// Shared harness: warm up (including one all-distinct worst-case batch so
/// every pooled buffer reaches its high-water mark), then count this
/// thread's allocations over `audit_steps` steady-state steps.
fn count_steady_state_allocs<B: PsDataPlane>(cluster: &B, audit_steps: usize) -> u64 {
    const ROWS: usize = 512;
    const T: usize = 4;
    const B_SZ: usize = 32;
    const H: usize = 2;
    const DIM: usize = 16;
    let n_nodes = 4;
    let n_slots = B_SZ * T * H;

    // Everything allocated OUTSIDE the audited region.
    let mut rng = Rng::new(7);
    let zipf = Zipf::new(ROWS, 1.1);
    let batches: Vec<Vec<u32>> = (0..audit_steps)
        .map(|_| (0..n_slots).map(|_| zipf.sample(&mut rng) as u32).collect())
        .collect();
    // worst case: all slots distinct → n_unique == n_slots, the maximum
    let distinct: Vec<u32> = (0..n_slots).map(|i| i as u32).collect();
    let grads = vec![0.01f32; B_SZ * T * DIM];
    let mut out = vec![0.0f32; B_SZ * T * DIM];
    let mut counts = vec![0u64; T * ROWS];
    let mut arena = PlanArena::new();

    // warmup: worst-case shape first, then two real batches
    for warm in [&distinct, &batches[0], &batches[1 % audit_steps]] {
        planned_step(cluster, &mut arena, warm, H, T, n_nodes, &grads, &mut out,
                     &mut counts, ROWS);
    }

    alloc::start_counting();
    for batch in &batches {
        planned_step(cluster, &mut arena, batch, H, T, n_nodes, &grads, &mut out,
                     &mut counts, ROWS);
    }
    alloc::stop_counting()
}

#[test]
fn inproc_planned_step_is_alloc_free_after_warmup() {
    let tables = vec![TableInfo { rows: 512, dim: 16 }; 4];
    let cluster = PsCluster::new(tables, 4, 9);
    let n = count_steady_state_allocs(&cluster, 16);
    assert_eq!(n, 0,
               "in-proc planned steady-state step must not allocate, saw {n} \
                allocations over 16 steps");
}

#[test]
fn threaded_planned_step_allocs_stay_bounded() {
    let tables = vec![TableInfo { rows: 512, dim: 16 }; 4];
    let cluster = ThreadedCluster::new(tables, 4, 9);
    let n_nodes = 4;
    let steps = 64;
    let n = count_steady_state_allocs(&cluster, steps);
    // Caller-side budget: per step, at most n_nodes gather sends plus
    // n_nodes apply sends; std mpsc allocates queue blocks amortized
    // (< 1 per send), every other buffer is pooled. 4·n_nodes + 8 per
    // step is a loose ceiling — the point is it does NOT scale with
    // batch size or unique-row count.
    let budget = (steps * (4 * n_nodes + 8)) as u64;
    assert!(n <= budget,
            "threaded caller-side allocations {n} exceed budget {budget} \
             over {steps} steps");
}
