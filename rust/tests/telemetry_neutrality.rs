//! Telemetry-plane acceptance tests (ISSUE 6).
//!
//! The telemetry plane is observation-only: enabling it must not perturb
//! training in any way — no RNG draws, no ordering changes, no ledger
//! charges. The bit-equality tests here run the same job with telemetry
//! off and on (both cluster backends, failures included) and require the
//! `TrainReport` to be IDENTICAL. The artifact test then checks that an
//! exporting run actually produces a loadable Chrome trace + metrics
//! snapshot covering the instrumented seams.

use std::sync::Mutex;

use cpr::config::{preset, JobConfig, PsBackendKind, Strategy};
use cpr::coordinator::{run_training, RunOptions, TrainReport};
use cpr::failure::{uniform_schedule, FailureEvent};
use cpr::runtime::{ModelExe, Runtime};
use cpr::util::json::Json;
use cpr::util::rng::Rng;

/// The span recorder's enable switch is process-global; serialize the
/// tests in this binary so an exporting run can't capture a concurrent
/// run's spans (and a "telemetry off" run really records nothing).
static TEST_LOCK: Mutex<()> = Mutex::new(());

fn load_model(preset_name: &str) -> ModelExe {
    Runtime::cpu()
        .expect("runtime")
        .load_model("artifacts", preset_name)
        .expect("loading model")
}

thread_local! {
    static MINI: std::cell::OnceCell<ModelExe> = const { std::cell::OnceCell::new() };
}

fn with_mini<R>(f: impl FnOnce(&ModelExe) -> R) -> R {
    MINI.with(|cell| f(cell.get_or_init(|| load_model("mini"))))
}

/// Small-but-learnable job config (same preset the integration suite uses).
fn test_cfg(strategy: Strategy) -> JobConfig {
    let mut cfg = preset("mini").unwrap();
    cfg.data.train_samples = 38_400; // 300 steps
    cfg.data.eval_samples = 12_800;
    cfg.checkpoint.strategy = strategy;
    cfg
}

fn sched(seed: u64, n: usize, victims: usize, t_total: f64, n_nodes: usize)
         -> Vec<FailureEvent> {
    let mut rng = Rng::new(seed);
    uniform_schedule(&mut rng, n, t_total, n_nodes, victims)
}

fn run(cfg: &JobConfig, schedule: Vec<FailureEvent>) -> TrainReport {
    with_mini(|model| {
        run_training(model, cfg, &RunOptions { schedule, ..Default::default() })
    })
    .expect("training run")
}

fn assert_reports_identical(off: &TrainReport, on: &TrainReport, tag: &str) {
    assert_eq!(off.final_auc, on.final_auc, "{tag}: AUC diverged");
    assert_eq!(off.final_logloss, on.final_logloss, "{tag}: logloss diverged");
    assert_eq!(off.pls, on.pls, "{tag}: PLS diverged");
    assert_eq!(off.steps_executed, on.steps_executed, "{tag}: steps diverged");
    assert_eq!(off.failures_seen, on.failures_seen, "{tag}");
    assert_eq!(off.ledger, on.ledger, "{tag}: overhead ledger diverged");
    assert_eq!(off.train_loss.points, on.train_loss.points,
               "{tag}: loss curve diverged");
}

// ---------------------------------------------------------------------------
// bit-equality: telemetry on vs off
// ---------------------------------------------------------------------------

#[test]
fn telemetry_is_bit_neutral_on_both_backends() {
    let _g = TEST_LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    for backend in [PsBackendKind::InProc, PsBackendKind::Threaded] {
        let mut cfg = test_cfg(Strategy::CprMfu);
        cfg.cluster.backend = backend;
        let n = cfg.cluster.n_emb_ps;
        let schedule = sched(23, 3, 2, cfg.cluster.t_total_h, n);

        let off = run(&cfg, schedule.clone());
        cfg.telemetry.enabled = true; // record in memory, no export dir
        cfg.telemetry.progress_steps = 100; // the progress line must be inert too
        let on = run(&cfg, schedule);

        assert_eq!(off.failures_seen, 3);
        assert_reports_identical(&off, &on, backend.name());
    }
}

#[test]
fn telemetry_is_bit_neutral_under_full_rewind() {
    // full recovery replays steps through the instrumented seams twice;
    // the replay must stay deterministic with the recorder on
    let _g = TEST_LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    let mut cfg = test_cfg(Strategy::Full);
    let n = cfg.cluster.n_emb_ps;
    let schedule = sched(3, 2, n / 2, cfg.cluster.t_total_h, n);
    let off = run(&cfg, schedule.clone());
    cfg.telemetry.enabled = true;
    let on = run(&cfg, schedule);
    assert!(on.ledger.lost_h > 0.0, "rewind path not exercised");
    assert_reports_identical(&off, &on, "full-rewind");
}

// ---------------------------------------------------------------------------
// export artifacts
// ---------------------------------------------------------------------------

#[test]
fn export_produces_trace_and_metrics_covering_the_seams() {
    let _g = TEST_LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    let tdir = std::env::temp_dir().join("cpr_telemetry_export_test");
    let cdir = std::env::temp_dir().join("cpr_telemetry_export_ckpt");
    std::fs::remove_dir_all(&tdir).ok();
    std::fs::remove_dir_all(&cdir).ok();

    let mut cfg = test_cfg(Strategy::Full);
    cfg.cluster.backend = PsBackendKind::Threaded;
    // a durable checkpoint dir so the fsync/rename spans actually fire
    cfg.checkpoint.dir = Some(cdir.to_str().unwrap().to_string());
    cfg.telemetry.dir = Some(tdir.to_str().unwrap().to_string()); // implies enabled
    let n = cfg.cluster.n_emb_ps;
    let r = run(&cfg, sched(3, 2, n / 2, cfg.cluster.t_total_h, n));
    assert_eq!(r.failures_seen, 2);

    // ---- trace.json: loadable Chrome Trace Event Format ----
    let text = std::fs::read_to_string(tdir.join("trace.json")).expect("trace.json");
    let doc = Json::parse(&text).expect("trace.json must parse");
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty());
    assert_eq!(doc.get("droppedSpans").unwrap().as_usize().unwrap(), 0,
               "mini run must fit the journal cap");
    let names: std::collections::BTreeSet<&str> = events
        .iter()
        .filter_map(|e| e.get("name").and_then(Json::as_str))
        .collect();
    for want in [
        "step", "gather", "barrier_wait", "train_step", "turnstile_wait",
        "apply_node", "quiesce", "ckpt_capture", "ckpt_publish", "ckpt_write",
        "ckpt_fsync", "ckpt_rename", "restore_all", "failure",
    ] {
        assert!(names.contains(want), "trace missing span {want:?}; have {names:?}");
    }
    // named tracks: the driver and its worker threads announce themselves
    let threads: Vec<&str> = events
        .iter()
        .filter(|e| e.get("ph").unwrap().as_str().unwrap() == "M")
        .filter_map(|e| e.get("args").unwrap().get("name").and_then(Json::as_str))
        .collect();
    assert!(!threads.is_empty(), "no thread_name metadata events");
    // per-node spans carry their node label
    let apply = events
        .iter()
        .find(|e| e.get("name").unwrap().as_str() == Some("apply_node"))
        .unwrap();
    assert!(apply.get("args").unwrap().get("node").unwrap().as_usize().is_some());

    // ---- metrics.json: per-node latency histograms ----
    let mtext = std::fs::read_to_string(tdir.join("metrics.json")).expect("metrics.json");
    let m = Json::parse(&mtext).expect("metrics.json must parse");
    let hists = m.get("histograms").unwrap();
    for node in 0..n {
        let key = format!("apply_node{{node={node}}}");
        let h = hists.get(&key).unwrap_or_else(|| panic!("missing histogram {key}"));
        assert!(h.get("count").unwrap().as_usize().unwrap() > 0, "{key} empty");
        assert!(h.get("p99").unwrap().as_f64().is_some(), "{key} lacks p99");
    }
    assert!(hists.get("gather").is_some(), "no gather latency histogram");
    assert!(hists.get("rows_per_step").is_some(), "rows/step not observed");
    assert!(m.get("gauges").unwrap().get("ckpt_in_flight").is_some());

    // ---- metrics.csv: one row per metric, stable header ----
    let csv = std::fs::read_to_string(tdir.join("metrics.csv")).expect("metrics.csv");
    let mut lines = csv.lines();
    assert_eq!(lines.next().unwrap(),
               "metric,kind,value,count,min,max,mean,p50,p95,p99,p999");
    assert!(lines.clone().any(|l| l.starts_with("gather,histogram")));
    assert!(lines.any(|l| l.starts_with("ckpt_in_flight,gauge")));

    std::fs::remove_dir_all(&tdir).ok();
    std::fs::remove_dir_all(&cdir).ok();
}

#[test]
fn disabled_telemetry_writes_nothing() {
    let _g = TEST_LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    let tdir = std::env::temp_dir().join("cpr_telemetry_disabled_test");
    std::fs::remove_dir_all(&tdir).ok();
    let cfg = test_cfg(Strategy::PartialNaive); // telemetry defaults: off
    let n = cfg.cluster.n_emb_ps;
    let r = run(&cfg, sched(29, 1, 1, cfg.cluster.t_total_h, n));
    assert_eq!(r.failures_seen, 1);
    assert!(!tdir.exists(), "disabled run must not create telemetry output");
}
