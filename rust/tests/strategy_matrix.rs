//! The per-strategy end-to-end matrix (one CI job leg per registered
//! checkpoint policy, on the threaded backend).
//!
//! CI's `strategy-matrix` job runs this binary once per strategy with
//! `CPR_STRATEGY=<name>`; without the variable (local `cargo test`) it
//! sweeps every policy the registry knows about, so a newly registered
//! policy is exercised end-to-end without editing this file.

use cpr::checkpoint::disk::DiskCheckpointer;
use cpr::config::{preset, CkptCodec, CkptFormat, PsBackendKind, Strategy};
use cpr::coordinator::{run_training, RunOptions};
use cpr::failure::FailureEvent;
use cpr::policy::registry;
use cpr::runtime::Runtime;

fn strategies_under_test() -> Vec<Strategy> {
    match std::env::var("CPR_STRATEGY") {
        Ok(name) => vec![Strategy::parse(&name)
            .expect("CPR_STRATEGY must be a registered strategy name")],
        Err(_) => registry::specs().into_iter().map(|s| s.strategy).collect(),
    }
}

/// `CPR_CKPT_FORMAT=v2` re-runs the scenario on the incremental
/// checkpoint engine (one CI leg does); default v1.
fn ckpt_format_under_test() -> CkptFormat {
    match std::env::var("CPR_CKPT_FORMAT") {
        Ok(name) => CkptFormat::parse(&name)
            .expect("CPR_CKPT_FORMAT must be v1 or v2"),
        Err(_) => CkptFormat::V1,
    }
}

/// `CPR_CKPT_CODEC=none|q8|q4|rle` re-runs the v2 scenario with an
/// encoded payload (the CI codec-matrix legs); default none. An empty
/// value also means none, so a matrix row can pass the variable
/// unconditionally.
fn ckpt_codec_under_test() -> CkptCodec {
    match std::env::var("CPR_CKPT_CODEC") {
        Ok(name) if !name.is_empty() => CkptCodec::parse(&name)
            .expect("CPR_CKPT_CODEC must be none, q8, q4, or rle"),
        _ => CkptCodec::None,
    }
}

#[test]
fn ci_matrix_lists_every_registered_strategy() {
    // the workflow's matrix is a hand-written list; catch drift against
    // the registry here (skipped when the workflow file is not present,
    // e.g. in a crate-only checkout)
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../.github/workflows/ci.yml");
    let Ok(yaml) = std::fs::read_to_string(path) else {
        return;
    };
    for name in registry::names() {
        assert!(yaml.contains(name),
                "CI strategy-matrix is missing {name:?} — keep the matrix in \
                 .github/workflows/ci.yml in sync with policy::registry::names()");
    }
}

#[test]
fn strategy_end_to_end_on_the_threaded_backend() {
    let model = Runtime::cpu()
        .expect("runtime")
        .load_model("artifacts", "mini")
        .expect("loading model");
    for strategy in strategies_under_test() {
        let mut cfg = preset("mini").unwrap();
        cfg.data.train_samples = 128 * 2 * 75; // 75 global steps at N = 2
        cfg.data.eval_samples = 6_400;
        cfg.cluster.backend = PsBackendKind::Threaded;
        cfg.cluster.n_trainers = 2;
        cfg.checkpoint.strategy = strategy.clone();
        // tight target so CPR policies (incl. adaptive) save several times
        cfg.checkpoint.target_pls = 0.02;
        let format = ckpt_format_under_test();
        cfg.checkpoint.format = format;
        // codec legs only bite under v2 (v1 publishes raw monoliths);
        // the durable chain below round-trips through the encoded files
        cfg.checkpoint.codec = ckpt_codec_under_test();
        let ckpt_dir = if format == CkptFormat::V2 {
            // v2 legs exercise the durable chain path end to end
            let dir = std::env::temp_dir()
                .join(format!("cpr_matrix_v2_{}_{}", strategy.name(),
                              cfg.checkpoint.codec.name()));
            std::fs::remove_dir_all(&dir).ok();
            cfg.checkpoint.dir = Some(dir.to_str().unwrap().to_string());
            Some(dir)
        } else {
            None
        };
        // mixed schedule: two PS losses + one trainer loss, at fixed times
        // chosen away from every strategy's save boundaries (so the first
        // PS loss always lands strictly after the last marker and PLS is
        // deterministically positive under partial recovery)
        let schedule = vec![
            FailureEvent { time_h: 13.0, victims: vec![1], trainer_victims: vec![] },
            FailureEvent { time_h: 27.5, victims: vec![5], trainer_victims: vec![] },
            FailureEvent { time_h: 40.0, victims: vec![], trainer_victims: vec![1] },
        ];
        let name = strategy.name();
        let r = run_training(&model, &cfg, &RunOptions { schedule, ..Default::default() })
            .unwrap_or_else(|e| panic!("{name}: run failed: {e:#}"));

        // universal invariants
        assert_eq!(r.strategy, name);
        assert_eq!(r.backend, "threaded", "{name}");
        assert_eq!(r.n_trainers, 2, "{name}");
        assert_eq!(r.failures_seen, 3, "{name}");
        assert!(r.final_auc > 0.55 && r.final_auc < 1.0, "{name}: AUC {}", r.final_auc);
        assert!(r.final_logloss.is_finite() && r.final_logloss > 0.0, "{name}");
        assert!(r.overhead_frac.is_finite() && r.overhead_frac > 0.0, "{name}");
        assert!(r.ledger.n_saves > 0, "{name}: no saves recorded");

        // per-mode semantics
        if strategy.is_partial() && !r.fell_back {
            assert_eq!(r.steps_executed, 75,
                       "{name}: partial recovery must not re-execute steps");
            assert_eq!(r.ledger.lost_h, 0.0, "{name}");
            assert!(r.pls > 0.0, "{name}: PS losses must accrue PLS");
        } else {
            assert!(r.steps_executed >= 75, "{name}: full recovery replays");
            assert_eq!(r.pls, 0.0, "{name}: full recovery loses no updates");
        }
        if strategy == Strategy::CprAdaptive {
            assert!(!r.ledger.replans.is_empty(),
                    "{name}: adaptive must re-plan at its majors");
            assert!(r.ledger.replans.iter().all(|&(_, t)| t.is_finite() && t > 0.0),
                    "{name}: re-planned intervals must be positive");
        } else {
            assert!(r.ledger.replans.is_empty(),
                    "{name}: static policies never re-plan");
        }
        if strategy.is_cpr() {
            assert!(r.plan.is_some(), "{name}: CPR strategies carry their plan");
            assert!(!r.fell_back,
                    "{name}: the paper cluster must not trigger fallback");
        }
        assert!(r.ledger.bytes_written > 0,
                "{name}: saves must account their I/O volume");
        if let Some(dir) = ckpt_dir {
            // the v2 leg published real chains: a MANIFEST exists, the
            // store loads back through the auto-detecting reader, and a
            // single node restores from its own chain only
            let d = dir.to_str().unwrap();
            let loaded = DiskCheckpointer::load_latest(d)
                .expect("v2 directory must load")
                .expect("v2 leg must have published a checkpoint");
            assert!(loaded.step > 0, "{name}: published marker must advance");
            let (snap, _, _) = DiskCheckpointer::load_latest_node(d, 0)
                .expect("node chain must load")
                .expect("manifest exists");
            assert_eq!(snap.shards, loaded.node_states()[0].shards(), "{name}");
            std::fs::remove_dir_all(&dir).ok();
        }
    }
}
