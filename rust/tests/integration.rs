//! End-to-end integration tests: artifact numerics vs the Python golden,
//! and full training-system behaviour (learning, recovery semantics,
//! overhead accounting) across strategies and cluster backends.
//!
//! Runs hermetically on the native executor; the golden-numerics test
//! additionally compares against the AOT artifacts when `make artifacts`
//! has produced them (it skips otherwise).

use std::collections::HashMap;
use std::io::Read;

use cpr::config::{preset, JobConfig, PsBackendKind, Strategy};
use cpr::coordinator::{run_training, RunOptions, TrainReport};
use cpr::failure::{uniform_schedule, FailureEvent};
use cpr::runtime::{ModelExe, Runtime};
use cpr::util::rng::Rng;

// The pjrt runtime's client is Rc-based (not Sync), so each test thread
// builds its own runtime + model. The native runtime synthesizes the model
// ABI from the preset when no artifacts are on disk.
fn load_model(preset_name: &str) -> ModelExe {
    Runtime::cpu()
        .expect("runtime")
        .load_model("artifacts", preset_name)
        .expect("loading model")
}

thread_local! {
    static MINI: std::cell::OnceCell<ModelExe> = const { std::cell::OnceCell::new() };
}

fn with_mini<R>(f: impl FnOnce(&ModelExe) -> R) -> R {
    MINI.with(|cell| f(cell.get_or_init(|| load_model("mini"))))
}

/// Small-but-learnable job config for tests (runs in a few seconds).
fn test_cfg(strategy: Strategy) -> JobConfig {
    let mut cfg = preset("mini").unwrap();
    cfg.data.train_samples = 38_400; // 300 steps
    cfg.data.eval_samples = 12_800;
    cfg.checkpoint.strategy = strategy;
    cfg
}

fn sched(seed: u64, n: usize, victims: usize, t_total: f64, n_nodes: usize)
         -> Vec<FailureEvent> {
    let mut rng = Rng::new(seed);
    uniform_schedule(&mut rng, n, t_total, n_nodes, victims)
}

fn run(cfg: &JobConfig, schedule: Vec<FailureEvent>) -> TrainReport {
    with_mini(|model| {
        run_training(model, cfg, &RunOptions { schedule, ..Default::default() })
    })
    .expect("training run")
}

// ---------------------------------------------------------------------------
// golden numerics
// ---------------------------------------------------------------------------

fn read_golden(path: &str) -> HashMap<String, Vec<f32>> {
    let mut f = std::fs::File::open(path).expect("golden.bin");
    let mut buf = Vec::new();
    f.read_to_end(&mut buf).unwrap();
    let mut pos = 0usize;
    let ru32 = |b: &[u8], p: &mut usize| -> u32 {
        let v = u32::from_le_bytes(b[*p..*p + 4].try_into().unwrap());
        *p += 4;
        v
    };
    let n = ru32(&buf, &mut pos);
    let mut out = HashMap::new();
    for _ in 0..n {
        let name_len = ru32(&buf, &mut pos) as usize;
        let name = String::from_utf8(buf[pos..pos + name_len].to_vec()).unwrap();
        pos += name_len;
        let count = ru32(&buf, &mut pos) as usize;
        let mut data = vec![0f32; count];
        for (i, d) in data.iter_mut().enumerate() {
            *d = f32::from_le_bytes(
                buf[pos + i * 4..pos + i * 4 + 4].try_into().unwrap());
        }
        pos += count * 4;
        out.insert(name, data);
    }
    out
}

fn assert_close(name: &str, got: &[f32], want: &[f32], atol: f32, rtol: f32) {
    assert_eq!(got.len(), want.len(), "{name}: length mismatch");
    let mut worst = 0.0f32;
    for (g, w) in got.iter().zip(want) {
        let err = (g - w).abs();
        let bound = atol + rtol * w.abs();
        if err > bound {
            worst = worst.max(err);
        }
    }
    assert!(worst == 0.0, "{name}: max violation {worst}");
}

/// THE critical test: the AOT artifact, executed from Rust through PJRT,
/// must reproduce jax's own numbers. Catches HLO round-trip corruption
/// (e.g. silently-elided large constants) that shape checks cannot see.
#[test]
fn golden_numerics_match_python() {
    if !std::path::Path::new("artifacts/mini/golden.bin").exists() {
        eprintln!("skipping: no AOT artifacts (run `make artifacts` to compare \
                   against the Python golden)");
        return;
    }
    for preset_name in ["mini", "kaggle_like"] {
        let model = load_model(preset_name);
        let g = read_golden(&format!("artifacts/{preset_name}/golden.bin"));
        let n_params = model.manifest.params.len();
        let mut params: Vec<cpr::runtime::PjRtBuffer> = (0..n_params)
            .map(|i| {
                let spec = &model.manifest.params[i];
                model.buffer(&g[&format!("param{i}")], &spec.shape).unwrap()
            })
            .collect();

        // predict first (params unchanged)
        let logits = model
            .predict(&g["dense"], &g["emb"], &params)
            .unwrap();
        assert_close(&format!("{preset_name}/logits"), &logits, &g["logits"],
                     1e-4, 1e-3);

        let out = model
            .train_step(&g["dense"], &g["emb"], &g["labels"], g["lr"][0],
                        &mut params)
            .unwrap();
        assert_close(&format!("{preset_name}/loss"), &[out.loss], &g["loss"],
                     1e-5, 1e-4);
        assert_close(&format!("{preset_name}/emb_grad"), &out.emb_grad,
                     &g["emb_grad"], 1e-6, 1e-3);
        let new_params = model.params_to_host(&params).unwrap();
        for (i, p) in new_params.iter().enumerate() {
            assert_close(&format!("{preset_name}/new_param{i}"), p,
                         &g[&format!("new_param{i}")], 1e-5, 1e-3);
        }
        // sanity: the embedding gradient must not be degenerate
        let gmax = out.emb_grad.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        assert!(gmax > 1e-6, "{preset_name}: embedding gradient ~ zero");
    }
}

// ---------------------------------------------------------------------------
// training-system behaviour
// ---------------------------------------------------------------------------

#[test]
fn training_learns_without_failures() {
    let cfg = test_cfg(Strategy::Full);
    let r = run(&cfg, vec![]);
    assert!(r.final_auc > 0.70, "AUC {}", r.final_auc);
    assert!(r.final_logloss < 0.67, "logloss {}", r.final_logloss);
    assert_eq!(r.failures_seen, 0);
    assert_eq!(r.pls, 0.0);
    // loss curve actually descends
    let first = r.train_loss.points.first().unwrap().1;
    let last = r.train_loss.points.last().unwrap().1;
    assert!(last < first - 0.01, "loss {first} -> {last}");
}

#[test]
fn full_recovery_reproduces_no_failure_model_exactly() {
    // full recovery rewinds and replays deterministically → same final AUC
    let cfg = test_cfg(Strategy::Full);
    let clean = run(&cfg, vec![]);
    let n = cfg.cluster.n_emb_ps;
    let failed = run(&cfg, sched(3, 2, n / 2, cfg.cluster.t_total_h, n));
    assert_eq!(failed.failures_seen, 2);
    assert!(failed.ledger.lost_h > 0.0);
    assert_eq!(clean.final_auc, failed.final_auc,
               "full recovery must be bit-identical to the clean run");
    // but it must re-execute extra steps
    assert!(failed.steps_executed > clean.steps_executed);
}

#[test]
fn partial_recovery_damages_accuracy_but_saves_time() {
    let cfg_clean = test_cfg(Strategy::Full);
    let clean = run(&cfg_clean, vec![]);
    let cfg = test_cfg(Strategy::PartialNaive);
    let n = cfg.cluster.n_emb_ps;
    // heavy damage: many failures, half the PS each
    let r = run(&cfg, sched(5, 8, n / 2, cfg.cluster.t_total_h, n));
    assert_eq!(r.failures_seen, 8);
    assert_eq!(r.steps_executed, 300, "partial must not re-execute steps");
    assert_eq!(r.ledger.lost_h, 0.0);
    assert!(r.pls > 0.0);
    assert!(r.final_auc < clean.final_auc,
            "heavy partial damage must cost AUC: {} !< {}",
            r.final_auc, clean.final_auc);
}

#[test]
fn cpr_reduces_overhead_vs_full() {
    let n = 8;
    let t_total = 56.0;
    let schedule = sched(7, 2, 1, t_total, n);
    let full = run(&test_cfg(Strategy::Full), schedule.clone());
    let cpr = run(&test_cfg(Strategy::CprVanilla), schedule.clone());
    let ssu = run(&test_cfg(Strategy::CprSsu), schedule);
    assert!(cpr.overhead_frac < 0.3 * full.overhead_frac,
            "CPR {} vs full {}", cpr.overhead_frac, full.overhead_frac);
    assert!(ssu.overhead_frac < 0.3 * full.overhead_frac);
    assert!(!cpr.fell_back);
    // CPR accuracy within a reasonable band of full recovery
    assert!((full.final_auc - cpr.final_auc).abs() < 0.02,
            "full {} cpr {}", full.final_auc, cpr.final_auc);
    assert!(ssu.final_auc >= cpr.final_auc - 0.01,
            "SSU should not be much worse than vanilla");
}

#[test]
fn cpr_falls_back_when_not_beneficial() {
    let mut cfg = test_cfg(Strategy::CprVanilla);
    cfg.cluster.t_fail_h = 0.05; // absurd failure rate
    cfg.checkpoint.target_pls = 0.01;
    let r = run(&cfg, vec![]);
    assert!(r.fell_back);
    assert_eq!(r.pls, 0.0, "fallback = full recovery = zero PLS");
}

#[test]
fn priority_strategies_save_partial_rows_and_stay_partial() {
    let n = 8;
    let schedule = sched(9, 2, 2, 56.0, n);
    for strategy in [Strategy::CprScar, Strategy::CprMfu, Strategy::CprSsu] {
        let r = run(&test_cfg(strategy.clone()), schedule.clone());
        assert!(!r.fell_back, "{strategy:?} fell back unexpectedly");
        assert_eq!(r.steps_executed, 300, "{strategy:?} re-executed steps");
        assert!(r.pls > 0.0, "{strategy:?} recorded no PLS");
        assert!(r.final_auc > 0.65, "{strategy:?} AUC {}", r.final_auc);
    }
}

#[test]
fn pls_accumulates_with_failure_count() {
    let cfg = test_cfg(Strategy::CprVanilla);
    let n = cfg.cluster.n_emb_ps;
    let few = run(&cfg, sched(11, 1, 1, cfg.cluster.t_total_h, n));
    let many = run(&cfg, sched(11, 6, 1, cfg.cluster.t_total_h, n));
    assert!(many.pls > few.pls,
            "more failures must accumulate more PLS: {} !> {}",
            many.pls, few.pls);
}

#[test]
fn overhead_ledger_matches_analytic_model() {
    // with k failures and s saves the ledger must equal the closed form
    let cfg = test_cfg(Strategy::PartialNaive);
    let n = cfg.cluster.n_emb_ps;
    let r = run(&cfg, sched(13, 3, 1, cfg.cluster.t_total_h, n));
    let c = &cfg.cluster;
    let expect_save = r.ledger.n_saves as f64 * c.o_save_h;
    assert!((r.ledger.save_h - expect_save).abs() < 1e-9);
    let expect_fail = 3.0 * (c.o_load_h + c.o_res_h);
    assert!((r.ledger.load_h + r.ledger.reschedule_h - expect_fail).abs() < 1e-9);
    assert_eq!(r.ledger.lost_h, 0.0);
}

#[test]
fn config_strategy_changes_are_honored() {
    // same schedule, different strategies → different overhead profiles
    let n = 8;
    let schedule = sched(15, 2, 1, 56.0, n);
    let full = run(&test_cfg(Strategy::Full), schedule.clone());
    let naive = run(&test_cfg(Strategy::PartialNaive), schedule);
    assert!(full.ledger.lost_h > 0.0);
    assert_eq!(naive.ledger.lost_h, 0.0);
    assert_eq!(full.ledger.n_saves, naive.ledger.n_saves,
               "same interval → same save count");
}

#[test]
fn durable_checkpoints_written_and_loadable() {
    use cpr::checkpoint::disk::DiskCheckpointer;
    let dir = std::env::temp_dir().join("cpr_durable_test");
    std::fs::remove_dir_all(&dir).ok();
    let mut cfg = test_cfg(Strategy::Full);
    cfg.checkpoint.dir = Some(dir.to_str().unwrap().to_string());
    let r = run(&cfg, vec![]);
    assert!(r.ledger.n_saves > 0);
    // the async writer persisted snapshots; the latest one must load and
    // carry a plausible position
    let latest = DiskCheckpointer::load_latest(dir.to_str().unwrap())
        .unwrap()
        .expect("no checkpoint written");
    assert!(latest.step > 0 && latest.step <= 300);
    assert_eq!(latest.samples, latest.step * 128);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn adagrad_training_learns_too() {
    let mut cfg = test_cfg(Strategy::CprSsu);
    cfg.train.emb_optimizer =
        cpr::embedding::EmbOptimizer::parse("adagrad").unwrap();
    cfg.train.emb_lr = 1.0;
    let n = cfg.cluster.n_emb_ps;
    let r = run(&cfg, sched(31, 2, 1, cfg.cluster.t_total_h, n));
    assert!(r.final_auc > 0.60, "adagrad AUC {}", r.final_auc);
    assert!(!r.fell_back);
}

// ---------------------------------------------------------------------------
// cluster backends + async checkpointing
// ---------------------------------------------------------------------------

#[test]
fn threaded_backend_matches_inproc_bit_exactly() {
    // the acceptance bar for the threaded runtime: the same job, same
    // seed, same failure schedule must produce IDENTICAL results —
    // requests are reassembled in slot order and updates applied in
    // sample order, so there is no nondeterminism to hide behind
    let mut cfg = test_cfg(Strategy::CprSsu);
    let n = cfg.cluster.n_emb_ps;
    let schedule = sched(17, 3, 2, cfg.cluster.t_total_h, n);
    let a = run(&cfg, schedule.clone());
    cfg.cluster.backend = PsBackendKind::Threaded;
    let b = run(&cfg, schedule);
    assert_eq!(a.backend, "inproc");
    assert_eq!(b.backend, "threaded");
    assert_eq!(b.failures_seen, 3);
    assert_eq!(a.final_auc, b.final_auc,
               "final AUC diverged across backends");
    assert_eq!(a.final_logloss, b.final_logloss,
               "final logloss diverged across backends");
    assert_eq!(a.pls, b.pls);
    assert_eq!(a.steps_executed, b.steps_executed);
}

#[test]
fn threaded_backend_full_recovery_rewind_is_equivalent() {
    // exercises restore_all + step rewind through the pipeline on the
    // threaded runtime: must still reproduce the clean model exactly
    let mut cfg = test_cfg(Strategy::Full);
    cfg.cluster.backend = PsBackendKind::Threaded;
    let clean = run(&cfg, vec![]);
    let n = cfg.cluster.n_emb_ps;
    let failed = run(&cfg, sched(3, 2, n / 2, cfg.cluster.t_total_h, n));
    assert_eq!(failed.failures_seen, 2);
    assert_eq!(clean.final_auc, failed.final_auc,
               "threaded full recovery must be bit-identical to clean");
    // and the threaded clean run matches the inproc clean run too
    let inproc_clean = run(&test_cfg(Strategy::Full), vec![]);
    assert_eq!(clean.final_auc, inproc_clean.final_auc);
}

#[test]
fn async_checkpoint_save_overlaps_a_training_step() {
    use cpr::checkpoint::async_pipeline::CheckpointPipeline;
    use cpr::checkpoint::CheckpointStore;
    use cpr::data::{Batch, SyntheticDataset};
    use cpr::embedding::{PsCluster, TableInfo};

    with_mini(|model| {
        let cfg = test_cfg(Strategy::Full);
        let m = &model.manifest;
        let tables: Vec<TableInfo> = cfg.data.table_rows.iter()
            .map(|&rows| TableInfo { rows, dim: m.emb_dim }).collect();
        let mut cluster = PsCluster::new(tables, cfg.cluster.n_emb_ps,
                                         cfg.data.seed ^ 0xEB);
        let dataset = SyntheticDataset::new(m.num_dense, &cfg.data);
        let mut params = model.init_params(1);
        // writer is artificially slow (400 ms per save): plenty of window
        // for a real training step to land while the save is in flight
        let pipeline = CheckpointPipeline::with_options(
            CheckpointStore::initial(&cluster, vec![]),
            &cpr::checkpoint::CheckpointOptions {
                write_delay: std::time::Duration::from_millis(400),
                ..Default::default()
            },
        ).unwrap();
        pipeline.full_save(&cluster, vec![], 1, 128);
        assert!(pipeline.in_flight() > 0, "save should be queued");
        // one full gather → train_step → scatter, start to finish
        let mut batch = Batch::zeros(m.batch, m.num_dense, m.num_sparse);
        dataset.fill_train_batch(0, &mut batch);
        let mut emb = vec![0.0f32; m.batch * m.num_sparse * m.emb_dim];
        cluster.gather(&batch.indices, &mut emb);
        let out = model.train_step(&batch.dense, &emb, &batch.labels, 0.05,
                                   &mut params).unwrap();
        cluster.sgd_update(&batch.indices, &out.emb_grad, 0.05);
        assert!(pipeline.in_flight() > 0,
                "the save must still be in flight after a full training \
                 step — it overlapped without blocking");
        pipeline.flush().unwrap();
        assert_eq!(pipeline.in_flight(), 0);
    });
}

#[test]
fn multi_hot_training_runs_and_learns() {
    let mut cfg = test_cfg(Strategy::CprSsu);
    cfg.data.hotness = 3;
    let n = cfg.cluster.n_emb_ps;
    let r = run(&cfg, sched(33, 2, 1, cfg.cluster.t_total_h, n));
    assert!(r.final_auc > 0.60, "multi-hot AUC {}", r.final_auc);
    assert_eq!(r.steps_executed, 300);
}

// ---------------------------------------------------------------------------
// the data-parallel trainer runtime
// ---------------------------------------------------------------------------

#[test]
fn multi_trainer_n1_is_bit_identical_to_reference_path() {
    // THE acceptance bar for the trainer-runtime refactor: an N = 1 run
    // through the TrainerPool driver must be bit-identical — final AUC,
    // logloss, PLS, loss curve, ledger — to the pre-refactor
    // single-trainer loop (preserved verbatim in coordinator::reference),
    // on BOTH cluster backends.
    use cpr::coordinator::reference::run_training_reference;
    for backend in [PsBackendKind::InProc, PsBackendKind::Threaded] {
        let mut cfg = test_cfg(Strategy::CprSsu);
        cfg.cluster.backend = backend;
        cfg.cluster.n_trainers = 1;
        let schedule = sched(17, 3, 2, cfg.cluster.t_total_h, cfg.cluster.n_emb_ps);
        let opts = RunOptions { schedule, ..Default::default() };
        let a = with_mini(|m| run_training(m, &cfg, &opts)).expect("driver run");
        let b = with_mini(|m| run_training_reference(m, &cfg, &opts))
            .expect("reference run");
        let name = backend.name();
        assert_eq!(a.n_trainers, 1);
        assert_eq!(a.backend, b.backend, "{name}");
        assert_eq!(a.final_auc, b.final_auc, "{name}: AUC diverged");
        assert_eq!(a.final_logloss, b.final_logloss, "{name}: logloss diverged");
        assert_eq!(a.pls, b.pls, "{name}: PLS diverged");
        assert_eq!(a.steps_executed, b.steps_executed, "{name}");
        assert_eq!(a.failures_seen, b.failures_seen, "{name}");
        assert_eq!(a.ledger, b.ledger, "{name}: overhead ledger diverged");
        assert_eq!(a.train_loss.points, b.train_loss.points,
                   "{name}: loss curve diverged");
    }
}

#[test]
fn multi_trainer_runs_are_deterministic_and_backend_identical() {
    // N = 2: gathers are genuinely concurrent, yet the rank-ordered
    // turnstile + gather barrier make the whole run reproducible and
    // identical across the inproc and threaded backends.
    let mut cfg = test_cfg(Strategy::CprSsu);
    cfg.cluster.n_trainers = 2;
    let schedule = sched(19, 2, 1, cfg.cluster.t_total_h, cfg.cluster.n_emb_ps);
    let opts = RunOptions { schedule, ..Default::default() };
    let a = with_mini(|m| run_training(m, &cfg, &opts)).unwrap();
    let b = with_mini(|m| run_training(m, &cfg, &opts)).unwrap();
    assert_eq!(a.final_auc, b.final_auc, "same config must reproduce exactly");
    assert_eq!(a.final_logloss, b.final_logloss);
    assert_eq!(a.pls, b.pls);
    cfg.cluster.backend = PsBackendKind::Threaded;
    let c = with_mini(|m| run_training(m, &cfg, &opts)).unwrap();
    assert_eq!(c.backend, "threaded");
    assert_eq!(a.final_auc, c.final_auc, "N=2 diverged across backends");
    assert_eq!(a.final_logloss, c.final_logloss);
    assert_eq!(a.train_loss.points, c.train_loss.points);
}

#[test]
fn n4_mixed_failure_is_backend_identical_and_n1_matches_reference() {
    // the sharded-seam acceptance scenario (ISSUE 3): with one PS loss +
    // one trainer loss under partial recovery,
    //   (a) the N = 1 driver run stays bit-identical to the preserved
    //       pre-refactor loop (coordinator::reference) on both backends
    //       under the same PS-failure schedule, and
    //   (b) the N = 4 runs are bit-identical ACROSS the two backends —
    //       per-node turnstile ordering leaves no nondeterminism to hide
    //       behind even under concurrent sharded scatters.
    use cpr::coordinator::reference::run_training_reference;
    let ps_only = vec![FailureEvent {
        time_h: 35.0,
        victims: vec![3],
        trainer_victims: vec![],
    }];
    for backend in [PsBackendKind::InProc, PsBackendKind::Threaded] {
        let mut cfg = test_cfg(Strategy::CprSsu);
        cfg.cluster.backend = backend;
        cfg.cluster.n_trainers = 1;
        let opts = RunOptions { schedule: ps_only.clone(), ..Default::default() };
        let a = with_mini(|m| run_training(m, &cfg, &opts)).unwrap();
        let b = with_mini(|m| run_training_reference(m, &cfg, &opts)).unwrap();
        let name = backend.name();
        assert_eq!(a.final_auc, b.final_auc,
                   "{name}: N=1 driver diverged from reference under failure");
        assert_eq!(a.final_logloss, b.final_logloss, "{name}");
        assert_eq!(a.pls, b.pls, "{name}");
        assert_eq!(a.train_loss.points, b.train_loss.points, "{name}");
    }
    let mut per_backend = Vec::new();
    for backend in [PsBackendKind::InProc, PsBackendKind::Threaded] {
        let mut cfg = test_cfg(Strategy::CprSsu);
        cfg.cluster.backend = backend;
        cfg.cluster.n_trainers = 4; // 38400 / (128·4) = 75 global steps
        let schedule = vec![
            FailureEvent {
                time_h: 20.0,
                victims: vec![],
                trainer_victims: vec![2],
            },
            FailureEvent {
                time_h: 35.0,
                victims: vec![3],
                trainer_victims: vec![],
            },
        ];
        let r = run(&cfg, schedule);
        let name = backend.name();
        assert_eq!(r.n_trainers, 4, "{name}");
        assert_eq!(r.failures_seen, 2, "{name}");
        assert_eq!(r.steps_executed, 75,
                   "{name}: partial recovery must not re-execute steps");
        assert_eq!(r.ledger.lost_h, 0.0, "{name}");
        assert!(r.pls > 0.0, "{name}: the PS loss must accrue PLS");
        assert!(r.final_auc.is_finite() && r.final_auc > 0.5 && r.final_auc < 1.0,
                "{name}: AUC {}", r.final_auc);
        assert!(r.final_logloss.is_finite() && r.final_logloss > 0.0,
                "{name}: logloss {}", r.final_logloss);
        assert!(r.overhead_frac.is_finite() && r.overhead_frac > 0.0, "{name}");
        assert!(!r.fell_back, "{name}");
        per_backend.push(r);
    }
    let (a, b) = (&per_backend[0], &per_backend[1]);
    assert_eq!(a.final_auc, b.final_auc,
               "N=4 mixed-failure AUC diverged across backends");
    assert_eq!(a.final_logloss, b.final_logloss,
               "N=4 mixed-failure logloss diverged across backends");
    assert_eq!(a.pls, b.pls, "N=4 mixed-failure PLS diverged across backends");
    assert_eq!(a.train_loss.points, b.train_loss.points,
               "N=4 mixed-failure loss curve diverged across backends");
}

#[test]
fn trainer_contention_n8_is_deterministic_and_backend_identical() {
    // the release-mode contention scenario (CI runs this under
    // `cargo test --release -- trainer`): 8 trainer threads hammer the
    // sharded data plane — concurrent gathers, per-node turnstile
    // scatters — and the run must still be reproducible run-to-run and
    // bit-identical across the inproc and threaded backends.
    let mut cfg = test_cfg(Strategy::PartialNaive);
    cfg.cluster.n_trainers = 8;
    cfg.data.train_samples = 128 * 8 * 8; // 8 global steps of 8 ranks
    cfg.data.eval_samples = 128 * 4;
    let opts = RunOptions::default();
    let a = with_mini(|m| run_training(m, &cfg, &opts)).unwrap();
    let b = with_mini(|m| run_training(m, &cfg, &opts)).unwrap();
    assert_eq!(a.n_trainers, 8);
    assert_eq!(a.steps_executed, 8);
    assert_eq!(a.final_auc, b.final_auc,
               "n=8 run must reproduce exactly under contention");
    assert_eq!(a.final_logloss, b.final_logloss);
    assert_eq!(a.train_loss.points, b.train_loss.points);
    cfg.cluster.backend = PsBackendKind::Threaded;
    let c = with_mini(|m| run_training(m, &cfg, &opts)).unwrap();
    assert_eq!(c.backend, "threaded");
    assert_eq!(a.final_auc, c.final_auc, "n=8 diverged across backends");
    assert_eq!(a.final_logloss, c.final_logloss);
    assert_eq!(a.train_loss.points, c.train_loss.points);
}

#[test]
fn multi_trainer_full_recovery_with_trainer_loss_rewinds_exactly() {
    // full recovery treats a trainer loss like any failure: reload +
    // rewind. The replay is deterministic, so the final model matches the
    // clean multi-trainer run exactly, at the cost of re-executed steps.
    let mut cfg = test_cfg(Strategy::Full);
    cfg.cluster.n_trainers = 2;
    let clean = run(&cfg, vec![]);
    let schedule = vec![FailureEvent {
        time_h: 30.0,
        victims: vec![],
        trainer_victims: vec![1],
    }];
    let failed = run(&cfg, schedule);
    assert_eq!(failed.failures_seen, 1);
    assert!(failed.ledger.lost_h > 0.0);
    assert!(failed.steps_executed > clean.steps_executed,
            "full recovery must re-execute steps");
    assert_eq!(clean.final_auc, failed.final_auc,
               "trainer-loss full recovery must replay to the same model");
    assert_eq!(clean.final_logloss, failed.final_logloss);
}

#[test]
fn single_trainer_partial_trainer_loss_reloads_dense_only() {
    // N = 1 partial recovery of a trainer loss: no surviving replica, so
    // the dense params reload (stale) from the checkpoint marker while
    // the Emb PS keeps its progress — no rewind, no PLS.
    let mut cfg = test_cfg(Strategy::PartialNaive);
    let clean = run(&cfg, vec![]);
    cfg.checkpoint.t_save_override_h = Some(8.0);
    let schedule = vec![FailureEvent {
        time_h: 45.0, // well past several marks; dense rolls back to 40 h
        victims: vec![],
        trainer_victims: vec![0],
    }];
    let r = run(&cfg, schedule);
    assert_eq!(r.failures_seen, 1);
    assert_eq!(r.steps_executed, 300, "no rewind under partial recovery");
    assert_eq!(r.ledger.lost_h, 0.0);
    assert_eq!(r.pls, 0.0, "trainer loss must not accrue embedding PLS");
    assert!(r.final_auc.is_finite() && r.final_auc > 0.5);
    // dense staleness is real damage, but embeddings kept their progress:
    // the run should stay in the same quality ballpark as the clean one
    assert!((clean.final_auc - r.final_auc).abs() < 0.1,
            "clean {} vs trainer-loss {}", clean.final_auc, r.final_auc);
}
