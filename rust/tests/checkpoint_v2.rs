//! Golden equivalence + crash consistency for checkpoint format v2
//! (ISSUE 5: incremental sharded checkpoint engine).
//!
//! v2 changes *what hits disk* — per-node base+delta chains instead of
//! monolithic store rewrites — and how full-content policies capture
//! (touched-row deltas instead of node snapshots). It must NOT change
//! training math:
//!
//! * every registered strategy produces bit-identical AUC / logloss /
//!   PLS / loss-curve / time-ledger under v2 vs v1, on both backends;
//! * v2 moves strictly fewer logical bytes for full-content strategies
//!   (delta capture) and identical bytes for the already-row-granular
//!   priority strategies;
//! * durable publication does not perturb the run, chains load back
//!   through the auto-detecting reader, one node restores from its own
//!   chain alone, and crash debris (orphan/truncated files, torn temp
//!   manifests) is invisible to readers.
//!
//! ISSUE 7 adds the **epsilon-bounded tier**: quantized codecs (q8/q4)
//! make restores deliberately non-bit-identical, so those runs assert
//! exact schedule/ledger-time equality but only epsilon-bounded AUC and
//! logloss against the fp32 run ([`CODEC_EPS`]).

use cpr::checkpoint::disk::DiskCheckpointer;
use cpr::checkpoint::v2;
use cpr::config::{preset, CkptCodec, CkptFormat, JobConfig, PsBackendKind, Strategy};
use cpr::coordinator::{run_training, RunOptions, TrainReport};
use cpr::failure::FailureEvent;
use cpr::policy::registry;
use cpr::runtime::{ModelExe, Runtime};

fn load_model() -> ModelExe {
    Runtime::cpu()
        .expect("runtime")
        .load_model("artifacts", "mini")
        .expect("loading model")
}

/// 100-global-step mini job with a tight PLS target (several saves).
fn grid_cfg(strategy: Strategy, backend: PsBackendKind, format: CkptFormat) -> JobConfig {
    let mut cfg = preset("mini").unwrap();
    cfg.data.train_samples = 128 * 100;
    cfg.data.eval_samples = 3_840;
    cfg.checkpoint.strategy = strategy;
    cfg.checkpoint.target_pls = 0.02;
    cfg.checkpoint.format = format;
    cfg.cluster.backend = backend;
    cfg
}

/// Two PS losses away from save boundaries, so partial restores really
/// read the mirror both runs.
fn schedule() -> Vec<FailureEvent> {
    vec![
        FailureEvent { time_h: 13.0, victims: vec![1], trainer_victims: vec![] },
        FailureEvent { time_h: 37.5, victims: vec![5, 2], trainer_victims: vec![] },
    ]
}

fn assert_training_identical(a: &TrainReport, b: &TrainReport, what: &str) {
    assert_eq!(a.final_auc, b.final_auc, "{what}: AUC diverged");
    assert_eq!(a.final_logloss, b.final_logloss, "{what}: logloss diverged");
    assert_eq!(a.pls, b.pls, "{what}: PLS diverged");
    assert_eq!(a.steps_executed, b.steps_executed, "{what}: steps diverged");
    assert_eq!(a.failures_seen, b.failures_seen, "{what}: failures diverged");
    assert_eq!(a.train_loss.points, b.train_loss.points,
               "{what}: loss curve diverged");
    // time charges are format-independent; only the I/O volume may move
    assert_eq!(a.ledger.save_h, b.ledger.save_h, "{what}: save_h diverged");
    assert_eq!(a.ledger.load_h, b.ledger.load_h, "{what}: load_h diverged");
    assert_eq!(a.ledger.lost_h, b.ledger.lost_h, "{what}: lost_h diverged");
    assert_eq!(a.ledger.reschedule_h, b.ledger.reschedule_h, "{what}");
    assert_eq!(a.ledger.n_saves, b.ledger.n_saves, "{what}: save count diverged");
    assert_eq!(a.ledger.n_failures, b.ledger.n_failures, "{what}");
    assert_eq!(a.ledger.bytes_restored, b.ledger.bytes_restored,
               "{what}: restore volume diverged");
}

/// The stated accuracy-drift budget for lossy checkpoint codecs: a
/// quantized run's final AUC and logloss must land within this of the
/// fp32 run. Check-N-Run reports negligible quality loss at byte-level
/// quantization; uniform q8 over dim-16 rows keeps per-value error below
/// `range/510`, and the mini job's restores touch a minority of steps.
const CODEC_EPS: f64 = 0.01;

/// The epsilon tier: everything time- and schedule-shaped stays exact
/// (the codec changes restored *values*, never cadence, failure
/// handling, or time charges); only the learned-quality metrics get the
/// epsilon.
fn assert_training_close(a: &TrainReport, b: &TrainReport, eps: f64, what: &str) {
    assert_eq!(a.steps_executed, b.steps_executed, "{what}: steps diverged");
    assert_eq!(a.failures_seen, b.failures_seen, "{what}: failures diverged");
    assert_eq!(a.pls, b.pls, "{what}: PLS diverged");
    assert_eq!(a.ledger.n_saves, b.ledger.n_saves, "{what}: save count diverged");
    assert_eq!(a.ledger.save_h, b.ledger.save_h, "{what}: save_h diverged");
    assert_eq!(a.ledger.load_h, b.ledger.load_h, "{what}: load_h diverged");
    assert_eq!(a.ledger.lost_h, b.ledger.lost_h, "{what}: lost_h diverged");
    assert!((a.final_auc - b.final_auc).abs() <= eps,
            "{what}: AUC drifted past ε={eps}: {} vs {}",
            a.final_auc, b.final_auc);
    assert!((a.final_logloss - b.final_logloss).abs() <= eps,
            "{what}: logloss drifted past ε={eps}: {} vs {}",
            a.final_logloss, b.final_logloss);
}

#[test]
fn v2_training_is_bit_identical_to_v1_for_every_strategy() {
    let model = load_model();
    for spec in registry::specs() {
        let strategy = spec.strategy;
        let opts = RunOptions { schedule: schedule(), ..Default::default() };
        let v1 = run_training(
            &model,
            &grid_cfg(strategy.clone(), PsBackendKind::InProc, CkptFormat::V1),
            &opts,
        )
        .expect("v1 run");
        let v2 = run_training(
            &model,
            &grid_cfg(strategy.clone(), PsBackendKind::InProc, CkptFormat::V2),
            &opts,
        )
        .expect("v2 run");
        let what = format!("v1-vs-v2/{}", strategy.name());
        assert_training_identical(&v1, &v2, &what);
        assert!(v1.ledger.bytes_written > 0, "{what}: v1 must account volume");
        assert!(v2.ledger.bytes_written > 0, "{what}: v2 must account volume");
        if strategy.priority() {
            // priority capture was already row-granular: identical volume
            assert_eq!(v2.ledger.bytes_written, v1.ledger.bytes_written, "{what}");
        } else {
            // full-content strategies now capture touched-row deltas:
            // strictly below full snapshots on a Zipf-skewed stream
            assert!(v2.ledger.bytes_written < v1.ledger.bytes_written,
                    "{what}: delta capture must shrink I/O volume \
                     ({} !< {})", v2.ledger.bytes_written, v1.ledger.bytes_written);
        }
    }
}

#[test]
fn v2_is_backend_identical() {
    let model = load_model();
    let opts = RunOptions { schedule: schedule(), ..Default::default() };
    let a = run_training(
        &model,
        &grid_cfg(Strategy::CprMfu, PsBackendKind::InProc, CkptFormat::V2),
        &opts,
    )
    .expect("inproc v2");
    let b = run_training(
        &model,
        &grid_cfg(Strategy::CprMfu, PsBackendKind::Threaded, CkptFormat::V2),
        &opts,
    )
    .expect("threaded v2");
    assert_training_identical(&a, &b, "v2/inproc-vs-threaded");
    assert_eq!(a.ledger.bytes_written, b.ledger.bytes_written);
}

#[test]
fn v2_durable_publication_does_not_perturb_training_and_loads_back() {
    let model = load_model();
    let dir = std::env::temp_dir().join("cpr_v2_e2e_durable");
    std::fs::remove_dir_all(&dir).ok();
    let opts = RunOptions { schedule: schedule(), ..Default::default() };
    let mem = run_training(
        &model,
        &grid_cfg(Strategy::CprMfu, PsBackendKind::InProc, CkptFormat::V2),
        &opts,
    )
    .expect("in-memory v2 run");
    let mut cfg = grid_cfg(Strategy::CprMfu, PsBackendKind::InProc, CkptFormat::V2);
    cfg.checkpoint.dir = Some(dir.to_str().unwrap().to_string());
    let durable = run_training(&model, &cfg, &opts).expect("durable v2 run");
    assert_training_identical(&mem, &durable, "v2/mem-vs-durable");
    assert_eq!(mem.ledger.bytes_written, durable.ledger.bytes_written);

    // the published chains load back through the auto-detecting reader
    let d = dir.to_str().unwrap();
    let loaded = DiskCheckpointer::load_latest(d)
        .expect("v2 dir loads")
        .expect("a checkpoint was published");
    assert!(loaded.step > 0, "position marker advanced on majors");
    let manifest = v2::read_manifest(&dir).unwrap().expect("MANIFEST exists");
    assert_eq!(manifest.chains.len(), cfg.cluster.n_emb_ps);

    // partial restore of one node touches only that node's chain: tear
    // every OTHER node's base and node 0 must still come back
    for chain in &manifest.chains[1..] {
        let p = dir.join(&chain.base);
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() / 2]).unwrap();
    }
    let (snap, step, _samples) = DiskCheckpointer::load_latest_node(d, 0)
        .expect("node 0 chain intact")
        .expect("manifest exists");
    assert_eq!(snap.node, 0);
    assert_eq!(step, loaded.step);
    assert_eq!(snap.shards, loaded.node_states()[0].shards());
    assert!(DiskCheckpointer::load_latest(d).is_err(),
            "the full-store load DOES read the torn chains");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn quantized_codecs_track_fp32_training_within_epsilon() {
    // the ISSUE 7 accuracy-drift gate: cpr-mfu with two PS failures
    // (restores actually read codec-fidelity values), durable v2 chains,
    // on BOTH backends — q8 and q4 must stay within CODEC_EPS of the
    // fp32 (codec=none) run while publishing strictly fewer bytes
    let model = load_model();
    let opts = RunOptions { schedule: schedule(), ..Default::default() };
    for backend in [PsBackendKind::InProc, PsBackendKind::Threaded] {
        let tag = format!("{backend:?}").to_lowercase();
        let base_dir = std::env::temp_dir().join(format!("cpr_codec_eps_{tag}_none"));
        std::fs::remove_dir_all(&base_dir).ok();
        let mut base_cfg = grid_cfg(Strategy::CprMfu, backend, CkptFormat::V2);
        base_cfg.checkpoint.dir = Some(base_dir.to_str().unwrap().to_string());
        let fp32 = run_training(&model, &base_cfg, &opts).expect("fp32 run");
        for codec in [CkptCodec::Q8, CkptCodec::Q4] {
            let what = format!("codec-eps/{tag}/{}", codec.name());
            let dir = std::env::temp_dir()
                .join(format!("cpr_codec_eps_{tag}_{}", codec.name()));
            std::fs::remove_dir_all(&dir).ok();
            let mut cfg = grid_cfg(Strategy::CprMfu, backend, CkptFormat::V2);
            cfg.checkpoint.dir = Some(dir.to_str().unwrap().to_string());
            cfg.checkpoint.codec = codec;
            let q = run_training(&model, &cfg, &opts).expect("quantized run");
            assert_training_close(&fp32, &q, CODEC_EPS, &what);
            assert!(q.ledger.bytes_written < fp32.ledger.bytes_written,
                    "{what}: encoded publishes must charge fewer bytes \
                     ({} !< {})", q.ledger.bytes_written,
                    fp32.ledger.bytes_written);
            // the encoded chain is a valid durable checkpoint
            let loaded = DiskCheckpointer::load_latest(dir.to_str().unwrap())
                .expect("encoded chain loads")
                .expect("a checkpoint was published");
            assert!(loaded.step > 0);
            std::fs::remove_dir_all(&dir).ok();
        }
        std::fs::remove_dir_all(&base_dir).ok();
    }
}

#[test]
fn v2_crash_debris_is_invisible_to_readers() {
    let model = load_model();
    let dir = std::env::temp_dir().join("cpr_v2_e2e_crash");
    std::fs::remove_dir_all(&dir).ok();
    let mut cfg = grid_cfg(Strategy::CprVanilla, PsBackendKind::InProc, CkptFormat::V2);
    cfg.checkpoint.dir = Some(dir.to_str().unwrap().to_string());
    let opts = RunOptions { schedule: schedule(), ..Default::default() };
    run_training(&model, &cfg, &opts).expect("durable v2 run");
    let d = dir.to_str().unwrap();
    let before = DiskCheckpointer::load_latest(d).unwrap().unwrap();
    // a writer killed mid-publish leaves renamed-but-unreferenced files
    // and torn temp files; none of it may reach a reader
    std::fs::write(dir.join("node0-delta-9999.bin"), b"CPRD-torn-mid-write").unwrap();
    std::fs::write(dir.join(".MANIFEST.tmp"), b"CPR-MANIFEST-V2\nseq ").unwrap();
    std::fs::write(dir.join(".node1-delta-9999.bin.tmp"), b"half").unwrap();
    let after = DiskCheckpointer::load_latest(d).unwrap().unwrap();
    assert_eq!(after, before, "debris must not change what readers see");
    let (snap_before, ..) =
        DiskCheckpointer::load_latest_node(d, 0).unwrap().unwrap();
    assert_eq!(snap_before.shards, before.node_states()[0].shards());
    std::fs::remove_dir_all(&dir).ok();
}
