//! `cargo bench` — hot-path micro-benchmarks on the custom harness
//! (`cpr::bench`; criterion is unavailable in the offline image).
//!
//! Sections:
//!   table1_*          — tracker time overheads (paper Table 1): SCAR vs
//!                       MFU vs SSU selection + record on a 1M-row table
//!   policy_overhead[] — per-step record_batch + select cost of each
//!                       tracker through the policy engine's
//!                       dyn PriorityTracker object vs the old concrete
//!                       calls, at 1e5 and 1e6 rows (dyn-dispatch +
//!                       injected-read cost of the policy seam)
//!   hotpath_*         — L3 coordinator primitives: PS gather/scatter,
//!                       checkpoint save/restore, AUC, data generation
//!   checkpoint_io[]   — durable publish cost per on-disk format: v1
//!                       monolithic rewrite vs v2 base re-publish vs v2
//!                       dirty-row delta (rows=1e5/1e6), the q8/q4
//!                       encoded delta publishes plus raw codec
//!                       encode/decode throughput, and the
//!                       one-node-chain partial restore; `[...,bytes]`
//!                       rows carry bytes-per-publish as throughput_per_s
//!   backend_*         — inproc vs threaded PS runtimes at B=128/512/2048
//!   scatter_contention[] — cross-node apply_grads throughput of the
//!                       sharded handle (per-node turnstiles) vs the
//!                       pre-refactor global-write-lock baseline, at
//!                       n=1/2/4/8 concurrent appliers on both backends
//!                       (disjoint-node batches — pure contention signal)
//!   trainer_scaling[] — end-to-end steps/sec at 1/2/4/8 data-parallel
//!                       trainers on both backends
//!   telemetry_overhead[] — the instrumented gather seam with the span
//!                       recorder off (one relaxed atomic load per site)
//!                       vs on (thread-local buffer push); the acceptance
//!                       bar reads the off-row against the pre-telemetry
//!                       baseline (must be within noise)
//!   serve_qps[]       — the read-only serving plane under live training
//!                       writes: open-loop Zipfian load at n=2/4/8 nodes
//!                       and 1e4/1e5 target QPS (rows carry completed
//!                       requests as throughput), a `during-ckpt` row
//!                       where a snapshot loop holds the quiesce token,
//!                       and `serve_contention[...,serving=off/on]` apply
//!                       throughput rows quantifying what serving costs
//!                       the training hot path
//!   gather_plan[]     — route-once batch plans (ISSUE 10): planned
//!                       (within-batch deduplicated, pooled-buffer) vs
//!                       unplanned gather throughput at zipf_s =
//!                       0.0/0.9/1.2 on both backends, plus
//!                       `[...,alloc_per_step]` rows whose
//!                       throughput_per_s carries the counted heap
//!                       allocations per steady-state planned step
//!                       (build + gather + per-node applies) — the CI
//!                       gate reads inproc == 0 and threaded-on ≥
//!                       1.3× threaded-off at zipf_s=1.2
//!   pjrt_*            — L2 executables from Rust: train_step / predict
//!                       latency, and the full e2e step
//!
//! `cargo bench -- --test` runs every section in quick mode (tiny warmup
//! and sampling budgets, shrunk training runs) — the CI bench-smoke step.
//! `--json <path>` dumps every row (including the scatter_contention
//! sharded-vs-global pair the acceptance numbers come from) to a
//! machine-readable file; CI uploads it as the bench artifact.
//! Results are recorded in EXPERIMENTS.md §Perf.

use cpr::bench::{record_external, write_json, Bench};
use cpr::checkpoint::codec;
use cpr::checkpoint::disk::{self, DiskCheckpointer};
use cpr::checkpoint::tracker::{MfuTracker, ScarTracker, SsuTracker};
use cpr::checkpoint::v2::V2Engine;
use cpr::checkpoint::writer_pool::WriterPool;
use cpr::checkpoint::CheckpointStore;
use cpr::cluster::{
    PlanArena, PsBackend, PsControlPlane, PsDataPlane, PsServePlane, ShardedPs,
    ThreadedCluster,
};
use cpr::config::{preset, CkptCodec, PsBackendKind};
use cpr::coordinator::{run_training, RunOptions};
use cpr::data::{Batch, SyntheticDataset};
use cpr::embedding::{PsCluster, TableInfo};
use cpr::metrics::auc;
use cpr::policy::PriorityTracker;
use cpr::runtime::Runtime;
use cpr::testing::alloc;
use cpr::util::dist::Zipf;
use cpr::util::rng::Rng;

// The whole bench binary runs under the counting allocator so the
// `gather_plan[...,alloc_per_step]` rows can audit the planned hot path.
// Counting is off unless a thread opts in via `alloc::count_allocs`, so
// every other section pays one thread-local read per allocation, nothing
// more.
#[global_allocator]
static ALLOC: alloc::CountingAlloc = alloc::CountingAlloc;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--test" || a == "--quick");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    // `--section <name>` runs one section at full budget (the CI
    // contention job uses `--section scatter_contention`)
    let section = args
        .iter()
        .position(|a| a == "--section")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let want = |name: &str| section.as_deref().map_or(true, |s| s == name);
    if quick {
        println!("(quick mode: tiny budgets — numbers are smoke, not perf)");
    }
    if want("table1") {
        table1(quick);
    }
    if want("policy_overhead") {
        policy_overhead(quick);
    }
    if want("hotpath") {
        hotpath(quick);
    }
    if want("checkpoint_io") {
        checkpoint_io(quick);
    }
    if want("backend") {
        backend_comparison(quick);
    }
    if want("scatter_contention") {
        scatter_contention(quick);
    }
    if want("trainer_scaling") {
        trainer_scaling(quick);
    }
    if want("telemetry_overhead") {
        telemetry_overhead(quick);
    }
    if want("serve_qps") {
        serve_qps(quick);
    }
    if want("gather_plan") {
        gather_plan(quick);
    }
    if want("pjrt") {
        pjrt(quick);
    }
    if let Some(path) = json_path {
        write_json(&path).expect("writing bench JSON");
        println!("\n(bench JSON written to {path})");
    }
}

/// A Bench with the section-appropriate budget.
fn bench(name: &str, quick: bool) -> Bench {
    let b = Bench::new(name);
    if quick {
        b.warmup_ms(5).measure_ms(20)
    } else {
        b
    }
}

// ---------------------------------------------------------------------------
// PsBackend comparison — inproc vs threaded
// ---------------------------------------------------------------------------

/// Gather / apply_grads throughput of the two cluster runtimes at several
/// batch sizes (mini-preset tables, 8 nodes, single-hot). The threaded
/// backend pays per-request channel + routing cost; this quantifies it.
fn backend_comparison(quick: bool) {
    println!("\n-- backend: inproc vs threaded PS runtimes (8 nodes, dim 16) --");
    let cfg = preset("mini").unwrap();
    let dim = 16usize;
    let t = cfg.model.num_sparse;
    let tables: Vec<TableInfo> = cfg.data.table_rows.iter()
        .map(|&rows| TableInfo { rows, dim }).collect();
    let inproc = PsCluster::new(tables.clone(), 8, 7);
    let threaded = ThreadedCluster::new(tables.clone(), 8, 7);
    let mut rng = Rng::new(9);
    let batches: &[usize] = if quick { &[128] } else { &[128, 512, 2048] };
    for &batch in batches {
        let indices: Vec<u32> = (0..batch * t)
            .map(|i| rng.below(cfg.data.table_rows[i % t] as u64) as u32)
            .collect();
        let mut out = vec![0.0f32; batch * t * dim];
        let grads = vec![0.001f32; batch * t * dim];
        let slots = (batch * t) as u64;
        bench(&format!("backend_gather[inproc,B={batch}]"), quick)
            .throughput(slots)
            .run(|| PsDataPlane::gather(&inproc, &indices, &mut out));
        bench(&format!("backend_gather[threaded,B={batch}]"), quick)
            .throughput(slots)
            .run(|| threaded.gather(&indices, &mut out));
        bench(&format!("backend_apply_grads[inproc,B={batch}]"), quick)
            .throughput(slots)
            .run(|| PsDataPlane::apply_grads(&inproc, &indices, 1, &grads, 0.01,
                                             cpr::embedding::EmbOptimizer::Sgd));
        bench(&format!("backend_apply_grads[threaded,B={batch}]"), quick)
            .throughput(slots)
            .run(|| threaded.apply_grads(&indices, 1, &grads, 0.01,
                                         cpr::embedding::EmbOptimizer::Sgd));
    }
}

// ---------------------------------------------------------------------------
// Scatter contention — sharded handle vs the pre-refactor global lock
// ---------------------------------------------------------------------------

/// Drive `n` appliers through the sharded handle's ordered scatter; each
/// applier `i` owns ticket stream `it·n + i`. Returns wall seconds.
fn run_contention_sharded<B: PsBackend + 'static>(
    shared: &ShardedPs<B>,
    batches: &[Vec<u32>],
    grads: &[f32],
    iters: usize,
) -> f64 {
    let n = batches.len();
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        for (rank, idx) in batches.iter().enumerate() {
            let shared = shared.clone();
            s.spawn(move || {
                for it in 0..iters {
                    shared.apply_grads_ordered(
                        (it * n + rank) as u64,
                        idx,
                        1,
                        grads,
                        0.01,
                        cpr::embedding::EmbOptimizer::Sgd,
                    );
                }
            });
        }
    });
    t0.elapsed().as_secs_f64()
}

/// The pre-refactor baseline: every apply behind one global write lock
/// (the exact shape of the retired `SharedPs(Arc<RwLock<B>>)` handle).
fn run_contention_global<B: PsBackend>(
    backend: &B,
    batches: &[Vec<u32>],
    grads: &[f32],
    iters: usize,
) -> f64 {
    let lock = std::sync::RwLock::new(backend);
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        for idx in batches {
            let lock = &lock;
            s.spawn(move || {
                for _ in 0..iters {
                    let g = lock.write().unwrap();
                    g.apply_grads(idx, 1, grads, 0.01,
                                  cpr::embedding::EmbOptimizer::Sgd);
                }
            });
        }
    });
    t0.elapsed().as_secs_f64()
}

/// Cross-node `apply_grads` throughput under contention: n appliers with
/// *disjoint-node* batches (applier i only touches node i), so any
/// serialization measured is pure locking, not row conflicts. Emits a
/// `scatter_contention[backend,n=N]` row for the sharded handle and a
/// `[...,global-lock]` row for the retired global-lock design — the
/// acceptance criterion reads both from the bench JSON.
fn scatter_contention(quick: bool) {
    println!("\n-- scatter_contention: sharded per-node locks vs global write lock --");
    let n_nodes = 8usize;
    let rows_per_node = 4096usize;
    let dim = 16usize;
    let tables = vec![TableInfo { rows: n_nodes * rows_per_node, dim }];
    let b = 2048usize; // slots per apply (1 table, single-hot)
    let iters = if quick { 4 } else { 96 };
    let grads = vec![0.001f32; b * dim];
    for backend in [PsBackendKind::InProc, PsBackendKind::Threaded] {
        let kind = backend.name();
        for n in [1usize, 2, 4, 8] {
            // applier i touches only node i: rows ≡ i (mod n_nodes)
            let batches: Vec<Vec<u32>> = (0..n)
                .map(|i| {
                    (0..b)
                        .map(|j| (i % n_nodes + (j % rows_per_node) * n_nodes) as u32)
                        .collect()
                })
                .collect();
            let slots = (n * iters * b) as u64;
            let (sharded_s, global_s) = match backend {
                PsBackendKind::InProc => {
                    let shared = ShardedPs::new(
                        PsCluster::new(tables.clone(), n_nodes, 7));
                    let sh = run_contention_sharded(&shared, &batches, &grads, iters);
                    let baseline = PsCluster::new(tables.clone(), n_nodes, 7);
                    let gl = run_contention_global(&baseline, &batches, &grads, iters);
                    (sh, gl)
                }
                PsBackendKind::Threaded => {
                    let shared = ShardedPs::new(
                        ThreadedCluster::new(tables.clone(), n_nodes, 7));
                    let sh = run_contention_sharded(&shared, &batches, &grads, iters);
                    let baseline = ThreadedCluster::new(tables.clone(), n_nodes, 7);
                    let gl = run_contention_global(&baseline, &batches, &grads, iters);
                    (sh, gl)
                }
            };
            let a = record_external(
                &format!("scatter_contention[{kind},n={n}]"), sharded_s, slots);
            let g = record_external(
                &format!("scatter_contention[{kind},n={n},global-lock]"),
                global_s, slots);
            println!(
                "  -> sharded/global speedup at {kind},n={n}: {:.2}x",
                g.mean_s() / a.mean_s().max(1e-12)
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Trainer scaling — end-to-end steps/sec vs data-parallel trainer count
// ---------------------------------------------------------------------------

/// One full (tiny) training run per (backend, n_trainers) point: N trainer
/// threads gathering concurrently from the shared PS, rank-ordered sparse
/// updates, replica allreduce at every step barrier. Reported as global
/// steps/sec and samples/sec (one global step = batch × N samples).
fn trainer_scaling(quick: bool) {
    println!("\n-- trainer_scaling: data-parallel steps/sec (mini-shaped job) --");
    let base = preset("mini").unwrap();
    let batch = base.model.batch;
    let rt = Runtime::cpu().unwrap();
    let model = rt.load_model("artifacts", "mini").unwrap();
    for backend in [PsBackendKind::InProc, PsBackendKind::Threaded] {
        for n in [1usize, 2, 4, 8] {
            let mut cfg = base.clone();
            cfg.cluster.backend = backend;
            cfg.cluster.n_trainers = n;
            // a multiple of batch × 8 divides every trainer count here;
            // the eval split stays tiny so steps/sec reflects training,
            // not the (n-independent) final evaluation
            cfg.data.train_samples = batch * 8 * if quick { 1 } else { 8 };
            cfg.data.eval_samples = batch * 2;
            let t0 = std::time::Instant::now();
            let r = run_training(&model, &cfg, &RunOptions::default())
                .expect("trainer_scaling run");
            let secs = t0.elapsed().as_secs_f64();
            let samples = r.steps_executed * (batch * n) as u64;
            println!(
                "trainer_scaling[{},n={n}]  {} global steps in {:.3} s  \
                 ({:.1} steps/s, {:.0} samples/s)",
                r.backend,
                r.steps_executed,
                secs,
                r.steps_executed as f64 / secs,
                samples as f64 / secs,
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Telemetry overhead — span recorder off vs on through a real seam
// ---------------------------------------------------------------------------

/// Drive the sharded handle's instrumented gather (the hottest span site)
/// with the recorder disabled and enabled. The off row IS the disabled-path
/// price every un-telemetered run pays: one relaxed atomic load per site.
/// The on row adds the monotonic-clock reads + thread-local buffer push.
fn telemetry_overhead(quick: bool) {
    use cpr::config::TelemetryConfig;
    use cpr::telemetry::TelemetrySink;
    println!("\n-- telemetry_overhead: instrumented gather, recorder off vs on --");
    let rows = 100_000usize;
    let dim = 16usize;
    let shared = ShardedPs::new(PsCluster::new(vec![TableInfo { rows, dim }], 8, 7));
    let mut rng = Rng::new(13);
    let batch = 2048usize;
    let indices: Vec<u32> =
        (0..batch).map(|_| rng.below(rows as u64) as u32).collect();
    let mut out = vec![0.0f32; batch * dim];

    bench("telemetry_overhead[off,rows=1e5]", quick)
        .throughput(batch as u64)
        .run(|| shared.gather_pooled(&indices, 1, &mut out));

    let mut sink = TelemetrySink::from_config(&TelemetryConfig {
        enabled: true,
        dir: None,
        progress_steps: 0,
    });
    bench("telemetry_overhead[on,rows=1e5]", quick)
        .throughput(batch as u64)
        .run(|| shared.gather_pooled(&indices, 1, &mut out));
    let stats = sink.export().expect("telemetry drain");
    println!("  -> {} spans recorded while on (drained in-memory; no dir set)",
             stats.spans);
}

// ---------------------------------------------------------------------------
// Serving plane — serve_gather under live training writes
// ---------------------------------------------------------------------------

/// What the concurrent writer thread does during a serving measurement.
#[derive(Clone, Copy)]
enum ServeLoad {
    /// trainer-shaped load: continuous ordered sparse applies + a view
    /// publish per "step" (the coordinator's cadence)
    Train,
    /// checkpoint-shaped load: repeatedly hold the quiesce token for a
    /// full-cluster snapshot — serving reads must ride through it
    Ckpt,
}

/// Run the open-loop load generator (if `qps` is set) for `run_ms`
/// against `shared` while one writer thread applies `load`. Returns the
/// serving report and the writer's completed iterations.
fn serve_point<B: PsBackend + 'static>(
    shared: &ShardedPs<B>,
    tables: &[TableInfo],
    n_nodes: usize,
    qps: Option<f64>,
    run_ms: u64,
    load: ServeLoad,
) -> (Option<cpr::serving::ServeReport>, u64) {
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
    use std::sync::Arc;
    let t = tables.len();
    let dim = tables[0].dim;
    let b = 256usize;
    let mut rng = Rng::new(31);
    let indices: Vec<u32> = (0..b * t)
        .map(|i| rng.below(tables[i % t].rows as u64) as u32)
        .collect();
    let grads = vec![0.001f32; b * t * dim];
    let stop = Arc::new(AtomicBool::new(false));
    let writes = Arc::new(AtomicU64::new(0));
    let writer = {
        let shared = shared.clone();
        let stop = Arc::clone(&stop);
        let writes = Arc::clone(&writes);
        std::thread::spawn(move || {
            let mut ticket = 0u64;
            while !stop.load(Ordering::Acquire) {
                match load {
                    ServeLoad::Train => {
                        shared.apply_grads_ordered(
                            ticket, &indices, 1, &grads, 0.01,
                            cpr::embedding::EmbOptimizer::Sgd);
                        ticket += 1;
                        shared.publish_serve_view();
                    }
                    ServeLoad::Ckpt => {
                        {
                            let q = shared.quiesce();
                            for node in 0..n_nodes {
                                std::hint::black_box(q.snapshot_node(node));
                            }
                        }
                        shared.publish_serve_view();
                    }
                }
                writes.fetch_add(1, Ordering::Relaxed);
            }
        })
    };
    let report = qps.map(|qps| {
        let lg = cpr::serving::LoadGen::start(
            Arc::new(shared.clone()), tables.to_vec(), n_nodes, qps, 4, 1.1, 17);
        std::thread::sleep(std::time::Duration::from_millis(run_ms));
        lg.stop()
    });
    if report.is_none() {
        std::thread::sleep(std::time::Duration::from_millis(run_ms));
    }
    stop.store(true, Ordering::Release);
    writer.join().expect("bench writer panicked");
    (report, writes.load(Ordering::Relaxed))
}

/// All serving rows for one backend: the qps × nodes grid, the
/// during-ckpt row, and the serving-off/on apply contention pair.
fn serve_qps_backend<B: PsBackend + 'static>(
    kind: &str,
    mk: impl Fn(usize) -> B,
    tables: &[TableInfo],
    ns: &[usize],
    qpss: &[f64],
    run_ms: u64,
) {
    for &n in ns {
        for &qps in qpss {
            let shared = ShardedPs::new(mk(n));
            let (report, _) =
                serve_point(&shared, tables, n, Some(qps), run_ms, ServeLoad::Train);
            let r = report.unwrap();
            let s = r.regime("steady").unwrap();
            record_external(&format!("serve_qps[{kind},n={n},qps={qps:.0}]"),
                            r.wall_secs, r.total_requests);
            println!("  {kind},n={n},qps={qps:.0}: achieved {:.0}/s  p50 {} us  \
                      p99 {} us  p999 {} us",
                     r.achieved_qps, s.p50_us, s.p99_us, s.p999_us);
        }
    }
    // serving while a checkpoint loop holds the quiesce token: the
    // non-blocking-read guarantee as a latency number
    let n = *ns.last().unwrap();
    let shared = ShardedPs::new(mk(n));
    let (report, snaps) =
        serve_point(&shared, tables, n, Some(qpss[0]), run_ms, ServeLoad::Ckpt);
    let r = report.unwrap();
    let s = r.regime("steady").unwrap();
    record_external(&format!("serve_qps[{kind},during-ckpt]"),
                    r.wall_secs, r.total_requests);
    println!("  {kind},during-ckpt: achieved {:.0}/s  p99 {} us  p999 {} us  \
              ({snaps} snapshot rounds)",
             r.achieved_qps, s.p99_us, s.p999_us);
    // what serving costs training: apply throughput, generator off vs on
    let slots_per_write = (256 * tables.len()) as u64;
    let run_s = run_ms as f64 / 1e3;
    let shared = ShardedPs::new(mk(n));
    let (_, off) = serve_point(&shared, tables, n, None, run_ms, ServeLoad::Train);
    let shared = ShardedPs::new(mk(n));
    let (_, on) = serve_point(&shared, tables, n, Some(*qpss.last().unwrap()),
                              run_ms, ServeLoad::Train);
    record_external(&format!("serve_contention[{kind},serving=off]"),
                    run_s, off * slots_per_write);
    record_external(&format!("serve_contention[{kind},serving=on]"),
                    run_s, on * slots_per_write);
    println!("  -> {kind}: apply slots/s {:.0} (serving off) vs {:.0} (serving on)",
             off as f64 * slots_per_write as f64 / run_s,
             on as f64 * slots_per_write as f64 / run_s);
}

/// Micro-guard for the PR 9 storage swap: one seqlock-validated row copy
/// through `AtomicF32s` (the shipping read path — Relaxed per-word atomic
/// loads + bitcast) against the pre-refactor per-float volatile-copy
/// loop over a plain `Vec<f32>`. Single-threaded and writer-free, so the
/// delta is the pure per-word instruction cost of the swap; the
/// `serve_qps`/`serve_contention` rows above cover the contended end.
fn serve_row_read_guard(quick: bool) {
    use cpr::cluster::{AtomicF32s, SeqLock};
    let dim = 16usize;
    let rows = 4096usize;
    let iters: u64 = if quick { 50_000 } else { 2_000_000 };
    let init: Vec<f32> = (0..rows * dim).map(|i| (i % 997) as f32 * 0.5).collect();
    let mut dst = vec![0.0f32; dim];
    let mut sink = 0.0f32;

    let words = AtomicF32s::from_f32s(&init);
    let lock = SeqLock::new();
    let t0 = std::time::Instant::now();
    for i in 0..iters {
        let off = (i as usize % rows) * dim;
        lock.read(|| words.load_into(off, &mut dst), || false)
            .expect("unkilled seqlock read");
        sink += dst[0];
    }
    let atomic_secs = t0.elapsed().as_secs_f64();
    record_external("serve_row_read[seqlock=atomic]", atomic_secs,
                    iters * dim as u64);

    // Pre-refactor baseline. The buffer is owned and unaliased here (no
    // concurrent writer exists in this loop), so the volatile reads are
    // sound: this measures the instruction sequence the old serving path
    // paid, not its (data-racing, since-removed) production behavior.
    // This file is the invariant lint's sole allowlisted non-src home of
    // `unsafe`/`read_volatile` for exactly this labeled baseline.
    let plain = init.clone();
    let t0 = std::time::Instant::now();
    for i in 0..iters {
        let off = (i as usize % rows) * dim;
        for (d, slot) in dst.iter_mut().enumerate() {
            // SAFETY: `plain` outlives the loop and `off + d` is in
            // bounds (`off < rows*dim`, `d < dim`, buffer is rows*dim);
            // no other thread aliases the buffer.
            *slot = unsafe { std::ptr::read_volatile(plain.as_ptr().add(off + d)) };
        }
        sink += dst[0];
    }
    let volatile_secs = t0.elapsed().as_secs_f64();
    record_external("serve_row_read[seqlock=volatile-baseline]", volatile_secs,
                    iters * dim as u64);
    println!("  serve_row_read: atomic {:.1}M f32/s vs volatile baseline \
              {:.1}M f32/s  (sink {sink:.0})",
             iters as f64 * dim as f64 / atomic_secs / 1e6,
             iters as f64 * dim as f64 / volatile_secs / 1e6);
}

fn serve_qps(quick: bool) {
    println!("\n-- serve_qps: read-only serving plane under live training writes --");
    serve_row_read_guard(quick);
    let dim = 16usize;
    let tables: Vec<TableInfo> =
        (0..4).map(|_| TableInfo { rows: 65_536, dim }).collect();
    let run_ms: u64 = if quick { 150 } else { 1000 };
    let ns: &[usize] = if quick { &[2] } else { &[2, 4, 8] };
    let qpss: &[f64] = if quick { &[10_000.0] } else { &[10_000.0, 100_000.0] };
    serve_qps_backend("inproc", |n| PsCluster::new(tables.clone(), n, 7),
                      &tables, ns, qpss, run_ms);
    serve_qps_backend("threaded", |n| ThreadedCluster::new(tables.clone(), n, 7),
                      &tables, ns, qpss, run_ms);
}

// ---------------------------------------------------------------------------
// Batch plans — route-once deduplicated gathers + the zero-alloc contract
// ---------------------------------------------------------------------------

/// Planned vs unplanned gather throughput for one backend across the
/// Zipf-skew grid. `dedup=off` times the unplanned `gather_pooled` scan;
/// `dedup=on` times the full planned path (`PlanArena::build` + the
/// plan-driven gather) — the plan build is deliberately *inside* the
/// timed region, since the trainer rebuilds it every step.
fn gather_plan_backend<B: PsDataPlane>(
    kind: &str,
    cluster: &B,
    quick: bool,
    rows: usize,
    t: usize,
    dim: usize,
    n_nodes: usize,
) {
    let b_sz = if quick { 256usize } else { 2048 };
    let n_slots = b_sz * t;
    let mut rng = Rng::new(23);
    let mut out = vec![0.0f32; n_slots * dim];
    let mut arena = PlanArena::new();
    for s in [0.0f64, 0.9, 1.2] {
        // s = 0 would make Zipf's normalizer uniform anyway, but the
        // implementation requires s > 0 — sample the uniform grid point
        // directly instead
        let indices: Vec<u32> = if s == 0.0 {
            (0..n_slots).map(|_| rng.below(rows as u64) as u32).collect()
        } else {
            let z = Zipf::new(rows, s);
            (0..n_slots).map(|_| z.sample(&mut rng) as u32).collect()
        };
        bench(&format!("gather_plan[{kind},zipf_s={s:.1},dedup=off]"), quick)
            .throughput(n_slots as u64)
            .run(|| cluster.gather_pooled(&indices, 1, &mut out));
        bench(&format!("gather_plan[{kind},zipf_s={s:.1},dedup=on]"), quick)
            .throughput(n_slots as u64)
            .run(|| {
                arena.build(&indices, 1, t, n_nodes);
                let (plan, scratch) = arena.parts_mut();
                cluster.gather_planned(plan, scratch, &mut out);
            });
        arena.build(&indices, 1, t, n_nodes);
        let plan = arena.plan();
        println!("  -> {kind},zipf_s={s:.1}: {} unique of {} slots \
                  ({:.1}% deduplicated)",
                 plan.n_unique(), plan.n_slots(),
                 100.0 * plan.dedup_hits() as f64 / plan.n_slots() as f64);
    }
}

/// The allocation audit as a JSON row: run `steps` steady-state planned
/// steps (plan build + planned gather + per-touched-node planned applies)
/// after a worst-case all-distinct warmup, count heap allocations on this
/// thread under the installed [`CountingAlloc`], and record
/// allocations-per-step with a 1-second denominator so the artifact's
/// `throughput_per_s` IS the count. The CI gate asserts the inproc row
/// is exactly 0; the threaded row bounds caller-side mpsc traffic only
/// (PS workers allocate on their own, uncounted threads).
fn gather_plan_alloc_row<B: PsDataPlane>(
    kind: &str,
    cluster: &B,
    quick: bool,
    rows: usize,
    t: usize,
    dim: usize,
    n_nodes: usize,
) {
    let b_sz = if quick { 256usize } else { 2048 };
    let n_slots = b_sz * t;
    let steps = if quick { 8u64 } else { 64 };
    let mut rng = Rng::new(29);
    let z = Zipf::new(rows, 1.2);
    let batches: Vec<Vec<u32>> = (0..steps)
        .map(|_| (0..n_slots).map(|_| z.sample(&mut rng) as u32).collect())
        .collect();
    let mut out = vec![0.0f32; n_slots * dim];
    let grads = vec![0.001f32; n_slots * dim];
    let mut arena = PlanArena::new();
    let mut planned_step = |indices: &[u32]| {
        arena.build(indices, 1, t, n_nodes);
        let (plan, scratch) = arena.parts_mut();
        cluster.gather_planned(plan, scratch, &mut out);
        for node in 0..n_nodes {
            if plan.touched().get(node) {
                cluster.apply_grads_planned_node(
                    node, plan, scratch, &grads, 0.01,
                    cpr::embedding::EmbOptimizer::Sgd);
            }
        }
    };
    // warmup: an all-distinct batch is the worst case for every pooled
    // buffer (n_unique == n_slots), so after it the arena's high-water
    // marks cover anything the audited Zipf batches can need
    let distinct: Vec<u32> = (0..n_slots).map(|i| (i % rows) as u32).collect();
    planned_step(&distinct);
    planned_step(&batches[0]);
    let (allocs, ()) = alloc::count_allocs(|| {
        for idx in &batches {
            planned_step(idx);
        }
    });
    let per_step = allocs / steps;
    record_external(&format!("gather_plan[{kind},alloc_per_step]"),
                    1.0, per_step);
    println!("  -> {kind}: {allocs} allocations over {steps} planned steps \
              ({per_step}/step)");
}

/// Route-once batch plans (ISSUE 10): dedup-on vs dedup-off gather
/// throughput across the skew grid on both backends, plus the
/// per-step allocation audit rows the CI perf gate reads.
fn gather_plan(quick: bool) {
    println!("\n-- gather_plan: route-once plans, dedup on/off, alloc audit --");
    let dim = 16usize;
    let t = 4usize;
    let rows = 100_000usize;
    let n_nodes = 4usize;
    let tables: Vec<TableInfo> = (0..t).map(|_| TableInfo { rows, dim }).collect();
    let inproc = PsCluster::new(tables.clone(), n_nodes, 7);
    gather_plan_backend("inproc", &inproc, quick, rows, t, dim, n_nodes);
    gather_plan_alloc_row("inproc", &inproc, quick, rows, t, dim, n_nodes);
    let threaded = ThreadedCluster::new(tables.clone(), n_nodes, 7);
    gather_plan_backend("threaded", &threaded, quick, rows, t, dim, n_nodes);
    gather_plan_alloc_row("threaded", &threaded, quick, rows, t, dim, n_nodes);
}

// ---------------------------------------------------------------------------
// Table 1 — tracker time overhead
// ---------------------------------------------------------------------------

fn table1(quick: bool) {
    println!("\n-- table1: tracker time overhead (1M rows, dim 16, r=0.125) --");
    let rows = if quick { 100_000usize } else { 1_000_000usize };
    let dim = 16usize;
    let k = rows / 8;
    let mask = vec![true];
    let cluster = PsCluster::new(vec![TableInfo { rows, dim }], 8, 1);
    let mut rng = Rng::new(1);
    // a realistic skewed access stream
    let zipf = Zipf::new(rows, 1.1);
    let accesses: Vec<u32> =
        (0..128 * 26).map(|_| zipf.sample(&mut rng) as u32).collect();

    let mut mfu = MfuTracker::new(&[rows], &mask);
    bench("table1_mfu_record_batch(3328 accesses)", quick)
        .throughput(accesses.len() as u64)
        .run(|| mfu.record_batch(&accesses, 1));
    bench("table1_mfu_top_k(select r*N of N)", quick)
        .run(|| mfu.top_k(0, k));

    let mut ssu = SsuTracker::new(&[k], &mask, 2, 3);
    bench("table1_ssu_record_batch(3328 accesses)", quick)
        .throughput(accesses.len() as u64)
        .run(|| ssu.record_batch(&accesses, 1));
    ssu.record_batch(&accesses, 1);
    bench("table1_ssu_drain", quick)
        .run(|| {
            ssu.record_batch(&accesses, 1);
            ssu.drain(0)
        });

    let scar = ScarTracker::new(&cluster, &mask);
    bench("table1_scar_top_k(select r*N of N, scans 16 f32/row)", quick)
        .run(|| scar.top_k(&cluster, 0, k));
    println!("(paper Table 1: SCAR ≈ O(N log N), MFU ≈ O(N log N), SSU ≈ O(N);\n \
              this impl uses O(N) select_nth for SCAR/MFU — see §Perf)");
}

// ---------------------------------------------------------------------------
// Policy-engine overhead — dyn PriorityTracker vs the concrete calls
// ---------------------------------------------------------------------------

/// Per-step tracker cost through the policy seam: `record_batch` +
/// `select` via `Box<dyn PriorityTracker>` (what `Prioritized` drives,
/// with the cluster read injected as `&dyn PsDataPlane`) against the
/// same work through the old concrete-type calls. The delta is the
/// dyn-dispatch price of the API redesign; rows at 1e5 and 1e6 rows
/// match the acceptance grid (quick mode runs 1e5 only).
fn policy_overhead(quick: bool) {
    println!("\n-- policy_overhead: dyn PriorityTracker vs concrete tracker calls --");
    let sizes: &[(usize, &str)] =
        if quick { &[(100_000, "1e5")] } else { &[(100_000, "1e5"), (1_000_000, "1e6")] };
    for &(rows, label) in sizes {
        let dim = 16usize;
        let k = rows / 8; // r = 0.125
        let mask = vec![true];
        let cluster = PsCluster::new(vec![TableInfo { rows, dim }], 8, 1);
        let mut rng = Rng::new(11);
        let zipf = Zipf::new(rows, 1.1);
        let accesses: Vec<u32> =
            (0..128 * 26).map(|_| zipf.sample(&mut rng) as u32).collect();
        let slots = accesses.len() as u64;

        // MFU: record + top-k select
        let mut mfu = MfuTracker::new(&[rows], &mask);
        bench(&format!("policy_overhead[mfu,rows={label},concrete]"), quick)
            .throughput(slots)
            .run(|| {
                mfu.record_batch(&accesses, 1);
                mfu.top_k(0, k)
            });
        let mut mfu_dyn: Box<dyn PriorityTracker> =
            Box::new(MfuTracker::new(&[rows], &mask));
        bench(&format!("policy_overhead[mfu,rows={label},dyn]"), quick)
            .throughput(slots)
            .run(|| {
                mfu_dyn.record_batch(&accesses, 1, 1);
                mfu_dyn.select(&cluster, 0, k)
            });

        // SSU: record + drain (select IS the drain in both APIs)
        let mut ssu = SsuTracker::new(&[k], &mask, 2, 3);
        bench(&format!("policy_overhead[ssu,rows={label},concrete]"), quick)
            .throughput(slots)
            .run(|| {
                ssu.record_batch(&accesses, 1);
                ssu.drain(0)
            });
        let mut ssu_dyn: Box<dyn PriorityTracker> =
            Box::new(SsuTracker::new(&[k], &mask, 2, 3));
        bench(&format!("policy_overhead[ssu,rows={label},dyn]"), quick)
            .throughput(slots)
            .run(|| {
                ssu_dyn.record_batch(&accesses, 1, 1);
                ssu_dyn.select(&cluster, 0, k)
            });

        // SCAR: the per-save cost is the full-table change scan; the dyn
        // path adds the injected &dyn PsDataPlane read on top of dispatch
        let scar = ScarTracker::new(&cluster, &mask);
        bench(&format!("policy_overhead[scar,rows={label},concrete]"), quick)
            .run(|| scar.top_k(&cluster, 0, k));
        let mut scar_dyn: Box<dyn PriorityTracker> =
            Box::new(ScarTracker::new(&cluster, &mask));
        bench(&format!("policy_overhead[scar,rows={label},dyn]"), quick)
            .run(|| {
                scar_dyn.record_batch(&accesses, 1, 1);
                scar_dyn.select(&cluster, 0, k)
            });
    }
}

// ---------------------------------------------------------------------------
// Checkpoint I/O — v1 monolithic publishes vs v2 base/delta chains
// ---------------------------------------------------------------------------

/// Disk-layer cost of one durable publish per format, at 1e5 and 1e6 rows
/// (dim 16, 8 nodes; the delta case dirties r·N = 12.5% of rows per
/// publish — a prioritized minor's shape). Each timing row has a
/// `[...,bytes]` sibling recorded with a 1-second denominator, so its
/// `throughput_per_s` in the JSON artifact IS the bytes one publish
/// wrote — the acceptance check "v2 delta publishes write strictly fewer
/// bytes than v1 full publishes" reads those two numbers. The
/// `v2-restore-node` row times the partial-restore read path (one node's
/// base+delta chain, not the whole checkpoint). The `v2-delta-q8`/`-q4`
/// rows repeat the delta shape with quantized encoding inside the writer
/// pool (their `[...,bytes]` siblings carry the *encoded* volume — the
/// ISSUE 7 "q8 ≤ ~30% of fp32 delta bytes" check reads them), and the
/// `codec-encode-*`/`codec-decode-*` rows report raw codec throughput.
fn checkpoint_io(quick: bool) {
    println!("\n-- checkpoint_io: v1 monolithic vs v2 incremental publishes --");
    let sizes: &[(usize, &str)] =
        if quick { &[(100_000, "1e5")] } else { &[(100_000, "1e5"), (1_000_000, "1e6")] };
    for &(rows, label) in sizes {
        let dim = 16usize;
        let n_nodes = 8usize;
        let cluster = PsCluster::new(vec![TableInfo { rows, dim }], n_nodes, 3);
        let mut store = CheckpointStore::initial(&cluster, vec![]);
        let k = (rows / 8).max(1); // r = 0.125 of the table per minor
        let hot: Vec<u32> = (0..k as u32).collect();
        let mut step = 0u64;

        // v1: every publish rewrites the whole store into one file
        let dir1 = std::env::temp_dir().join(format!("cpr_bench_ckpt_v1_{label}"));
        std::fs::remove_dir_all(&dir1).ok();
        std::fs::create_dir_all(&dir1).unwrap();
        let v1_bytes = store.size_bytes() as u64;
        bench(&format!("checkpoint_io[v1-full,rows={label}]"), quick)
            .throughput(v1_bytes)
            .run(|| {
                step += 1;
                store.mark_position(vec![], step, step * 128);
                disk::publish(&dir1, &store, 2).unwrap()
            });
        record_external(&format!("checkpoint_io[v1-full,rows={label},bytes]"),
                        1.0, v1_bytes);

        // v2-base: forced re-base every publish (a priority major) — the
        // per-node files fan out over the writer pool
        let dir2 = std::env::temp_dir().join(format!("cpr_bench_ckpt_v2_{label}"));
        std::fs::remove_dir_all(&dir2).ok();
        let mut eng = V2Engine::open(&dir2, WriterPool::for_nodes(n_nodes), 0.5,
                                     CkptCodec::None)
            .unwrap();
        let mut base_bytes = 0u64;
        bench(&format!("checkpoint_io[v2-base,rows={label}]"), quick)
            .throughput(v1_bytes)
            .run(|| {
                step += 1;
                store.mark_position(vec![], step, step * 128);
                base_bytes = eng.publish(&mut store, true, true).unwrap();
            });
        record_external(&format!("checkpoint_io[v2-base,rows={label},bytes]"),
                        1.0, base_bytes);

        // v2-delta: only the hot 12.5% of rows dirty per publish (the
        // prioritized-minor shape); huge compact_frac keeps every publish
        // a pure delta so the row isn't a base/delta mix
        let dir3 = std::env::temp_dir().join(format!("cpr_bench_ckpt_v2d_{label}"));
        std::fs::remove_dir_all(&dir3).ok();
        let mut engd = V2Engine::open(&dir3, WriterPool::for_nodes(n_nodes), 1e12,
                                      CkptCodec::None)
            .unwrap();
        engd.publish(&mut store, true, false).unwrap(); // initial bases
        let mut delta_bytes = 0u64;
        bench(&format!("checkpoint_io[v2-delta,rows={label}]"), quick)
            .throughput(cpr::checkpoint::rows_io_bytes(k, dim))
            .run(|| {
                step += 1;
                store.save_rows(&cluster, 0, &hot);
                store.mark_position(vec![], step, step * 128);
                delta_bytes = engd.publish(&mut store, true, false).unwrap();
            });
        record_external(&format!("checkpoint_io[v2-delta,rows={label},bytes]"),
                        1.0, delta_bytes);
        println!("  -> v1-full/v2-delta bytes per publish at rows={label}: \
                  {v1_bytes} / {delta_bytes} = {:.1}x",
                 v1_bytes as f64 / delta_bytes.max(1) as f64);

        // v2-delta under quantizing codecs: the identical minor shape,
        // encoded inside the writer-pool jobs. The ISSUE 7 acceptance
        // bar reads these `[...,bytes]` rows against the fp32 delta row:
        // q8 must land at ≤ ~30% on the 1e5-row config.
        for codec_kind in [CkptCodec::Q8, CkptCodec::Q4] {
            let cname = codec_kind.name();
            let dirc = std::env::temp_dir()
                .join(format!("cpr_bench_ckpt_v2d_{cname}_{label}"));
            std::fs::remove_dir_all(&dirc).ok();
            let mut engc = V2Engine::open(&dirc, WriterPool::for_nodes(n_nodes),
                                          1e12, codec_kind)
                .unwrap();
            engc.publish(&mut store, true, false).unwrap(); // initial bases
            let mut enc_bytes = 0u64;
            bench(&format!("checkpoint_io[v2-delta-{cname},rows={label}]"), quick)
                .throughput(cpr::checkpoint::rows_io_bytes(k, dim))
                .run(|| {
                    step += 1;
                    store.save_rows(&cluster, 0, &hot);
                    store.mark_position(vec![], step, step * 128);
                    enc_bytes = engc.publish(&mut store, true, false).unwrap();
                });
            record_external(
                &format!("checkpoint_io[v2-delta-{cname},rows={label},bytes]"),
                1.0, enc_bytes);
            println!("  -> {cname}/fp32 delta bytes per publish at rows={label}: \
                      {enc_bytes} / {delta_bytes} = {:.1}%",
                     100.0 * enc_bytes as f64 / delta_bytes.max(1) as f64);
            std::fs::remove_dir_all(&dirc).ok();
        }

        // raw codec throughput off the disk path: one node's delta
        // payload (k rows × dim) through encode, then decode of the
        // encoded blob — MB/s per codec in the JSON artifact
        let mut rng = Rng::new(42);
        let vals: Vec<f32> = (0..k * dim).map(|_| rng.f32() - 0.5).collect();
        let payload_bytes = (vals.len() * 4) as u64;
        for codec_kind in [CkptCodec::Q8, CkptCodec::Q4, CkptCodec::Rle] {
            let cname = codec_kind.name();
            let c = codec::codec(codec_kind);
            bench(&format!("checkpoint_io[codec-encode-{cname},rows={label}]"),
                  quick)
                .throughput(payload_bytes)
                .run(|| c.encode(codec::Payload::Rows, &vals));
            let enc = c.encode(codec::Payload::Rows, &vals);
            bench(&format!("checkpoint_io[codec-decode-{cname},rows={label}]"),
                  quick)
                .throughput(payload_bytes)
                .run(|| c.decode(codec::Payload::Rows, &enc, vals.len()).unwrap());
        }

        // v2 partial restore: read ONE node's chain back. Give dir2's
        // chains a representative delta tail first (bounded by the 0.5
        // compaction threshold), so the row times real base+delta replay,
        // not a bare base read.
        for _ in 0..2 {
            step += 1;
            store.save_rows(&cluster, 0, &hot);
            store.mark_position(vec![], step, step * 128);
            eng.publish(&mut store, true, false).unwrap();
        }
        let dir2_str = dir2.to_str().unwrap().to_string();
        bench(&format!("checkpoint_io[v2-restore-node,rows={label}]"), quick)
            .run(|| {
                DiskCheckpointer::load_latest_node(&dir2_str, 3).unwrap().unwrap()
            });

        std::fs::remove_dir_all(&dir1).ok();
        std::fs::remove_dir_all(&dir2).ok();
        std::fs::remove_dir_all(&dir3).ok();
    }
}

// ---------------------------------------------------------------------------
// L3 hot paths
// ---------------------------------------------------------------------------

fn hotpath(quick: bool) {
    println!("\n-- hotpath: coordinator primitives (mini preset shapes) --");
    let cfg = preset("mini").unwrap();
    let dim = cfg.model.emb_dim;
    let tables: Vec<TableInfo> = cfg.data.table_rows.iter()
        .map(|&rows| TableInfo { rows, dim }).collect();
    let cluster = PsCluster::new(tables, cfg.cluster.n_emb_ps, 7);
    let ds = SyntheticDataset::new(cfg.model.num_dense, &cfg.data);
    let mut batch = Batch::zeros(cfg.model.batch, cfg.model.num_dense,
                                 cfg.model.num_sparse);
    ds.fill_train_batch(0, &mut batch);
    let mut emb = vec![0.0f32; cfg.model.batch * cfg.model.num_sparse * dim];
    let grads = vec![0.001f32; emb.len()];

    bench("hotpath_data_fill_batch(128x(13+26))", quick)
        .throughput(cfg.model.batch as u64)
        .run(|| ds.fill_train_batch(12800, &mut batch));
    bench("hotpath_ps_gather(128x26xd16)", quick)
        .throughput((cfg.model.batch * cfg.model.num_sparse) as u64)
        .run(|| cluster.gather(&batch.indices, &mut emb));
    bench("hotpath_ps_sgd_update(128x26xd16)", quick)
        .throughput((cfg.model.batch * cfg.model.num_sparse) as u64)
        .run(|| cluster.sgd_update(&batch.indices, &grads, 0.01));

    let mut store = CheckpointStore::initial(&cluster, vec![]);
    bench("hotpath_checkpoint_full_save(77k rows)", quick)
        .throughput(cluster.total_params() as u64)
        .run(|| store.full_save(&cluster, vec![], 1, 128));
    bench("hotpath_checkpoint_restore_node", quick)
        .run(|| store.restore_node(&cluster, 3));

    let mut rng = Rng::new(5);
    let scores: Vec<f32> = (0..50_000).map(|_| rng.f32()).collect();
    let labels: Vec<f32> = (0..50_000)
        .map(|_| (rng.f64() < 0.5) as u32 as f32).collect();
    bench("hotpath_auc(50k samples)", quick)
        .throughput(50_000)
        .run(|| auc(&scores, &labels));

    let zipf = Zipf::new(1_000_000, 1.1);
    bench("hotpath_zipf_sample", quick)
        .run(|| zipf.sample(&mut rng));
}

// ---------------------------------------------------------------------------
// PJRT executables (requires `make artifacts`)
// ---------------------------------------------------------------------------

fn pjrt(quick: bool) {
    if !std::path::Path::new("artifacts/mini/manifest.json").exists() {
        println!("\n-- pjrt: SKIPPED (run `make artifacts`) --");
        return;
    }
    println!("\n-- pjrt: AOT executables from the Rust hot path --");
    let rt = Runtime::cpu().unwrap();
    for preset_name in ["mini", "kaggle_like", "terabyte_like"] {
        let model = rt.load_model("artifacts", preset_name).unwrap();
        let m = &model.manifest;
        let cfg = preset(preset_name).unwrap();
        let dim = m.emb_dim;
        let tables: Vec<TableInfo> = cfg.data.table_rows.iter()
            .map(|&rows| TableInfo { rows, dim }).collect();
        let cluster = PsCluster::new(tables, cfg.cluster.n_emb_ps, 7);
        let ds = SyntheticDataset::new(m.num_dense, &cfg.data);
        let mut batch = Batch::zeros(m.batch, m.num_dense, m.num_sparse);
        ds.fill_train_batch(0, &mut batch);
        let mut emb = vec![0.0f32; m.batch * m.num_sparse * dim];
        cluster.gather(&batch.indices, &mut emb);
        let mut params = model.init_params(1);

        bench(&format!("pjrt_train_step[{preset_name}]"), quick)
            .throughput(m.batch as u64)
            .run(|| {
                model.train_step(&batch.dense, &emb, &batch.labels, 0.05,
                                 &mut params).unwrap()
            });
        bench(&format!("pjrt_predict[{preset_name}]"), quick)
            .throughput(m.batch as u64)
            .run(|| model.predict(&batch.dense, &emb, &params).unwrap());
        let mut step_id = 0u64;
        bench(&format!("pjrt_e2e_step[{preset_name}] gather+step+scatter"), quick)
            .throughput(m.batch as u64)
            .run(|| {
                ds.fill_train_batch(step_id * m.batch as u64, &mut batch);
                cluster.gather(&batch.indices, &mut emb);
                let out = model.train_step(&batch.dense, &emb, &batch.labels,
                                           0.05, &mut params).unwrap();
                cluster.sgd_update(&batch.indices, &out.emb_grad, 0.05);
                step_id += 1;
            });
    }
}
