//! `cargo bench` — hot-path micro-benchmarks on the custom harness
//! (`cpr::bench`; criterion is unavailable in the offline image).
//!
//! Sections:
//!   table1_*   — tracker time overheads (paper Table 1): SCAR vs MFU vs
//!                SSU selection + record on a 1M-row table, r = 0.125
//!   hotpath_*  — L3 coordinator primitives: PS gather/scatter, checkpoint
//!                save/restore, AUC, synthetic data generation
//!   pjrt_*     — L2 executables from Rust: train_step / predict latency,
//!                and the full e2e step (gather + step + scatter)
//!
//! Results are recorded in EXPERIMENTS.md §Perf.

use cpr::bench::Bench;
use cpr::checkpoint::tracker::{MfuTracker, ScarTracker, SsuTracker};
use cpr::checkpoint::CheckpointStore;
use cpr::cluster::{PsBackend, ThreadedCluster};
use cpr::config::preset;
use cpr::data::{Batch, SyntheticDataset};
use cpr::embedding::{PsCluster, TableInfo};
use cpr::metrics::auc;
use cpr::runtime::Runtime;
use cpr::util::dist::Zipf;
use cpr::util::rng::Rng;

fn main() {
    table1();
    hotpath();
    backend_comparison();
    pjrt();
}

// ---------------------------------------------------------------------------
// PsBackend comparison — inproc vs threaded
// ---------------------------------------------------------------------------

/// Gather / apply_grads throughput of the two cluster runtimes at several
/// batch sizes (mini-preset tables, 8 nodes, single-hot). The threaded
/// backend pays per-request channel + routing cost; this quantifies it.
fn backend_comparison() {
    println!("\n-- backend: inproc vs threaded PS runtimes (8 nodes, dim 16) --");
    let cfg = preset("mini").unwrap();
    let dim = 16usize;
    let t = cfg.model.num_sparse;
    let tables: Vec<TableInfo> = cfg.data.table_rows.iter()
        .map(|&rows| TableInfo { rows, dim }).collect();
    let mut inproc = PsCluster::new(tables.clone(), 8, 7);
    let mut threaded = ThreadedCluster::new(tables.clone(), 8, 7);
    let mut rng = Rng::new(9);
    for batch in [128usize, 512, 2048] {
        let indices: Vec<u32> = (0..batch * t)
            .map(|i| rng.below(cfg.data.table_rows[i % t] as u64) as u32)
            .collect();
        let mut out = vec![0.0f32; batch * t * dim];
        let grads = vec![0.001f32; batch * t * dim];
        let slots = (batch * t) as u64;
        Bench::new(&format!("backend_gather[inproc,B={batch}]"))
            .throughput(slots)
            .run(|| PsBackend::gather(&inproc, &indices, &mut out));
        Bench::new(&format!("backend_gather[threaded,B={batch}]"))
            .throughput(slots)
            .run(|| threaded.gather(&indices, &mut out));
        Bench::new(&format!("backend_apply_grads[inproc,B={batch}]"))
            .throughput(slots)
            .run(|| PsBackend::apply_grads(&mut inproc, &indices, 1, &grads, 0.01,
                                           cpr::embedding::EmbOptimizer::Sgd));
        Bench::new(&format!("backend_apply_grads[threaded,B={batch}]"))
            .throughput(slots)
            .run(|| threaded.apply_grads(&indices, 1, &grads, 0.01,
                                         cpr::embedding::EmbOptimizer::Sgd));
    }
}

// ---------------------------------------------------------------------------
// Table 1 — tracker time overhead
// ---------------------------------------------------------------------------

fn table1() {
    println!("\n-- table1: tracker time overhead (1M rows, dim 16, r=0.125) --");
    let rows = 1_000_000usize;
    let dim = 16usize;
    let k = rows / 8;
    let mask = vec![true];
    let cluster = PsCluster::new(vec![TableInfo { rows, dim }], 8, 1);
    let mut rng = Rng::new(1);
    // a realistic skewed access stream
    let zipf = Zipf::new(rows, 1.1);
    let accesses: Vec<u32> =
        (0..128 * 26).map(|_| zipf.sample(&mut rng) as u32).collect();

    let mut mfu = MfuTracker::new(&[rows], &mask);
    Bench::new("table1_mfu_record_batch(3328 accesses)")
        .throughput(accesses.len() as u64)
        .run(|| mfu.record_batch(&accesses, 1));
    Bench::new("table1_mfu_top_k(select 125k of 1M)")
        .run(|| mfu.top_k(0, k));

    let mut ssu = SsuTracker::new(&[k], &mask, 2, 3);
    Bench::new("table1_ssu_record_batch(3328 accesses)")
        .throughput(accesses.len() as u64)
        .run(|| ssu.record_batch(&accesses, 1));
    ssu.record_batch(&accesses, 1);
    Bench::new("table1_ssu_drain")
        .run(|| {
            ssu.record_batch(&accesses, 1);
            ssu.drain(0)
        });

    let scar = ScarTracker::new(&cluster, &mask);
    Bench::new("table1_scar_top_k(select 125k of 1M, scans 16 f32/row)")
        .run(|| scar.top_k(&cluster, 0, k));
    println!("(paper Table 1: SCAR ≈ O(N log N), MFU ≈ O(N log N), SSU ≈ O(N);\n \
              this impl uses O(N) select_nth for SCAR/MFU — see §Perf)");
}

// ---------------------------------------------------------------------------
// L3 hot paths
// ---------------------------------------------------------------------------

fn hotpath() {
    println!("\n-- hotpath: coordinator primitives (mini preset shapes) --");
    let cfg = preset("mini").unwrap();
    let dim = cfg.model.emb_dim;
    let tables: Vec<TableInfo> = cfg.data.table_rows.iter()
        .map(|&rows| TableInfo { rows, dim }).collect();
    let mut cluster = PsCluster::new(tables, cfg.cluster.n_emb_ps, 7);
    let ds = SyntheticDataset::new(cfg.model.num_dense, &cfg.data);
    let mut batch = Batch::zeros(cfg.model.batch, cfg.model.num_dense,
                                 cfg.model.num_sparse);
    ds.fill_train_batch(0, &mut batch);
    let mut emb = vec![0.0f32; cfg.model.batch * cfg.model.num_sparse * dim];
    let grads = vec![0.001f32; emb.len()];

    Bench::new("hotpath_data_fill_batch(128x(13+26))")
        .throughput(cfg.model.batch as u64)
        .run(|| ds.fill_train_batch(12800, &mut batch));
    Bench::new("hotpath_ps_gather(128x26xd16)")
        .throughput((cfg.model.batch * cfg.model.num_sparse) as u64)
        .run(|| cluster.gather(&batch.indices, &mut emb));
    Bench::new("hotpath_ps_sgd_update(128x26xd16)")
        .throughput((cfg.model.batch * cfg.model.num_sparse) as u64)
        .run(|| cluster.sgd_update(&batch.indices, &grads, 0.01));

    let mut store = CheckpointStore::initial(&cluster, vec![]);
    Bench::new("hotpath_checkpoint_full_save(77k rows)")
        .throughput(cluster.total_params() as u64)
        .run(|| store.full_save(&cluster, vec![], 1, 128));
    Bench::new("hotpath_checkpoint_restore_node")
        .run(|| store.restore_node(&mut cluster, 3));

    let mut rng = Rng::new(5);
    let scores: Vec<f32> = (0..50_000).map(|_| rng.f32()).collect();
    let labels: Vec<f32> = (0..50_000)
        .map(|_| (rng.f64() < 0.5) as u32 as f32).collect();
    Bench::new("hotpath_auc(50k samples)")
        .throughput(50_000)
        .run(|| auc(&scores, &labels));

    let zipf = Zipf::new(1_000_000, 1.1);
    Bench::new("hotpath_zipf_sample")
        .run(|| zipf.sample(&mut rng));
}

// ---------------------------------------------------------------------------
// PJRT executables (requires `make artifacts`)
// ---------------------------------------------------------------------------

fn pjrt() {
    if !std::path::Path::new("artifacts/mini/manifest.json").exists() {
        println!("\n-- pjrt: SKIPPED (run `make artifacts`) --");
        return;
    }
    println!("\n-- pjrt: AOT executables from the Rust hot path --");
    let rt = Runtime::cpu().unwrap();
    for preset_name in ["mini", "kaggle_like", "terabyte_like"] {
        let model = rt.load_model("artifacts", preset_name).unwrap();
        let m = &model.manifest;
        let cfg = preset(preset_name).unwrap();
        let dim = m.emb_dim;
        let tables: Vec<TableInfo> = cfg.data.table_rows.iter()
            .map(|&rows| TableInfo { rows, dim }).collect();
        let mut cluster = PsCluster::new(tables, cfg.cluster.n_emb_ps, 7);
        let ds = SyntheticDataset::new(m.num_dense, &cfg.data);
        let mut batch = Batch::zeros(m.batch, m.num_dense, m.num_sparse);
        ds.fill_train_batch(0, &mut batch);
        let mut emb = vec![0.0f32; m.batch * m.num_sparse * dim];
        cluster.gather(&batch.indices, &mut emb);
        let mut params = model.init_params(1);

        Bench::new(&format!("pjrt_train_step[{preset_name}]"))
            .throughput(m.batch as u64)
            .run(|| {
                model.train_step(&batch.dense, &emb, &batch.labels, 0.05,
                                 &mut params).unwrap()
            });
        Bench::new(&format!("pjrt_predict[{preset_name}]"))
            .throughput(m.batch as u64)
            .run(|| model.predict(&batch.dense, &emb, &params).unwrap());
        let mut step_id = 0u64;
        Bench::new(&format!("pjrt_e2e_step[{preset_name}] gather+step+scatter"))
            .throughput(m.batch as u64)
            .run(|| {
                ds.fill_train_batch(step_id * m.batch as u64, &mut batch);
                cluster.gather(&batch.indices, &mut emb);
                let out = model.train_step(&batch.dense, &emb, &batch.labels,
                                           0.05, &mut params).unwrap();
                cluster.sgd_update(&batch.indices, &out.emb_grad, 0.05);
                step_id += 1;
            });
    }
}
