//! `cargo bench` — hot-path micro-benchmarks on the custom harness
//! (`cpr::bench`; criterion is unavailable in the offline image).
//!
//! Sections:
//!   table1_*          — tracker time overheads (paper Table 1): SCAR vs
//!                       MFU vs SSU selection + record on a 1M-row table
//!   hotpath_*         — L3 coordinator primitives: PS gather/scatter,
//!                       checkpoint save/restore, AUC, data generation
//!   backend_*         — inproc vs threaded PS runtimes at B=128/512/2048
//!   trainer_scaling[] — end-to-end steps/sec at 1/2/4/8 data-parallel
//!                       trainers on both backends
//!   pjrt_*            — L2 executables from Rust: train_step / predict
//!                       latency, and the full e2e step
//!
//! `cargo bench -- --test` runs every section in quick mode (tiny warmup
//! and sampling budgets, shrunk training runs) — the CI bench-smoke step.
//! Results are recorded in EXPERIMENTS.md §Perf.

use cpr::bench::Bench;
use cpr::checkpoint::tracker::{MfuTracker, ScarTracker, SsuTracker};
use cpr::checkpoint::CheckpointStore;
use cpr::cluster::{PsBackend, ThreadedCluster};
use cpr::config::{preset, PsBackendKind};
use cpr::coordinator::{run_training, RunOptions};
use cpr::data::{Batch, SyntheticDataset};
use cpr::embedding::{PsCluster, TableInfo};
use cpr::metrics::auc;
use cpr::runtime::Runtime;
use cpr::util::dist::Zipf;
use cpr::util::rng::Rng;

fn main() {
    let quick = std::env::args().any(|a| a == "--test" || a == "--quick");
    if quick {
        println!("(quick mode: tiny budgets — numbers are smoke, not perf)");
    }
    table1(quick);
    hotpath(quick);
    backend_comparison(quick);
    trainer_scaling(quick);
    pjrt(quick);
}

/// A Bench with the section-appropriate budget.
fn bench(name: &str, quick: bool) -> Bench {
    let b = Bench::new(name);
    if quick {
        b.warmup_ms(5).measure_ms(20)
    } else {
        b
    }
}

// ---------------------------------------------------------------------------
// PsBackend comparison — inproc vs threaded
// ---------------------------------------------------------------------------

/// Gather / apply_grads throughput of the two cluster runtimes at several
/// batch sizes (mini-preset tables, 8 nodes, single-hot). The threaded
/// backend pays per-request channel + routing cost; this quantifies it.
fn backend_comparison(quick: bool) {
    println!("\n-- backend: inproc vs threaded PS runtimes (8 nodes, dim 16) --");
    let cfg = preset("mini").unwrap();
    let dim = 16usize;
    let t = cfg.model.num_sparse;
    let tables: Vec<TableInfo> = cfg.data.table_rows.iter()
        .map(|&rows| TableInfo { rows, dim }).collect();
    let mut inproc = PsCluster::new(tables.clone(), 8, 7);
    let mut threaded = ThreadedCluster::new(tables.clone(), 8, 7);
    let mut rng = Rng::new(9);
    let batches: &[usize] = if quick { &[128] } else { &[128, 512, 2048] };
    for &batch in batches {
        let indices: Vec<u32> = (0..batch * t)
            .map(|i| rng.below(cfg.data.table_rows[i % t] as u64) as u32)
            .collect();
        let mut out = vec![0.0f32; batch * t * dim];
        let grads = vec![0.001f32; batch * t * dim];
        let slots = (batch * t) as u64;
        bench(&format!("backend_gather[inproc,B={batch}]"), quick)
            .throughput(slots)
            .run(|| PsBackend::gather(&inproc, &indices, &mut out));
        bench(&format!("backend_gather[threaded,B={batch}]"), quick)
            .throughput(slots)
            .run(|| threaded.gather(&indices, &mut out));
        bench(&format!("backend_apply_grads[inproc,B={batch}]"), quick)
            .throughput(slots)
            .run(|| PsBackend::apply_grads(&mut inproc, &indices, 1, &grads, 0.01,
                                           cpr::embedding::EmbOptimizer::Sgd));
        bench(&format!("backend_apply_grads[threaded,B={batch}]"), quick)
            .throughput(slots)
            .run(|| threaded.apply_grads(&indices, 1, &grads, 0.01,
                                         cpr::embedding::EmbOptimizer::Sgd));
    }
}

// ---------------------------------------------------------------------------
// Trainer scaling — end-to-end steps/sec vs data-parallel trainer count
// ---------------------------------------------------------------------------

/// One full (tiny) training run per (backend, n_trainers) point: N trainer
/// threads gathering concurrently from the shared PS, rank-ordered sparse
/// updates, replica allreduce at every step barrier. Reported as global
/// steps/sec and samples/sec (one global step = batch × N samples).
fn trainer_scaling(quick: bool) {
    println!("\n-- trainer_scaling: data-parallel steps/sec (mini-shaped job) --");
    let base = preset("mini").unwrap();
    let batch = base.model.batch;
    let rt = Runtime::cpu().unwrap();
    let model = rt.load_model("artifacts", "mini").unwrap();
    for backend in [PsBackendKind::InProc, PsBackendKind::Threaded] {
        for n in [1usize, 2, 4, 8] {
            let mut cfg = base.clone();
            cfg.cluster.backend = backend;
            cfg.cluster.n_trainers = n;
            // a multiple of batch × 8 divides every trainer count here;
            // the eval split stays tiny so steps/sec reflects training,
            // not the (n-independent) final evaluation
            cfg.data.train_samples = batch * 8 * if quick { 1 } else { 8 };
            cfg.data.eval_samples = batch * 2;
            let t0 = std::time::Instant::now();
            let r = run_training(&model, &cfg, &RunOptions::default())
                .expect("trainer_scaling run");
            let secs = t0.elapsed().as_secs_f64();
            let samples = r.steps_executed * (batch * n) as u64;
            println!(
                "trainer_scaling[{},n={n}]  {} global steps in {:.3} s  \
                 ({:.1} steps/s, {:.0} samples/s)",
                r.backend,
                r.steps_executed,
                secs,
                r.steps_executed as f64 / secs,
                samples as f64 / secs,
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Table 1 — tracker time overhead
// ---------------------------------------------------------------------------

fn table1(quick: bool) {
    println!("\n-- table1: tracker time overhead (1M rows, dim 16, r=0.125) --");
    let rows = if quick { 100_000usize } else { 1_000_000usize };
    let dim = 16usize;
    let k = rows / 8;
    let mask = vec![true];
    let cluster = PsCluster::new(vec![TableInfo { rows, dim }], 8, 1);
    let mut rng = Rng::new(1);
    // a realistic skewed access stream
    let zipf = Zipf::new(rows, 1.1);
    let accesses: Vec<u32> =
        (0..128 * 26).map(|_| zipf.sample(&mut rng) as u32).collect();

    let mut mfu = MfuTracker::new(&[rows], &mask);
    bench("table1_mfu_record_batch(3328 accesses)", quick)
        .throughput(accesses.len() as u64)
        .run(|| mfu.record_batch(&accesses, 1));
    bench("table1_mfu_top_k(select r*N of N)", quick)
        .run(|| mfu.top_k(0, k));

    let mut ssu = SsuTracker::new(&[k], &mask, 2, 3);
    bench("table1_ssu_record_batch(3328 accesses)", quick)
        .throughput(accesses.len() as u64)
        .run(|| ssu.record_batch(&accesses, 1));
    ssu.record_batch(&accesses, 1);
    bench("table1_ssu_drain", quick)
        .run(|| {
            ssu.record_batch(&accesses, 1);
            ssu.drain(0)
        });

    let scar = ScarTracker::new(&cluster, &mask);
    bench("table1_scar_top_k(select r*N of N, scans 16 f32/row)", quick)
        .run(|| scar.top_k(&cluster, 0, k));
    println!("(paper Table 1: SCAR ≈ O(N log N), MFU ≈ O(N log N), SSU ≈ O(N);\n \
              this impl uses O(N) select_nth for SCAR/MFU — see §Perf)");
}

// ---------------------------------------------------------------------------
// L3 hot paths
// ---------------------------------------------------------------------------

fn hotpath(quick: bool) {
    println!("\n-- hotpath: coordinator primitives (mini preset shapes) --");
    let cfg = preset("mini").unwrap();
    let dim = cfg.model.emb_dim;
    let tables: Vec<TableInfo> = cfg.data.table_rows.iter()
        .map(|&rows| TableInfo { rows, dim }).collect();
    let mut cluster = PsCluster::new(tables, cfg.cluster.n_emb_ps, 7);
    let ds = SyntheticDataset::new(cfg.model.num_dense, &cfg.data);
    let mut batch = Batch::zeros(cfg.model.batch, cfg.model.num_dense,
                                 cfg.model.num_sparse);
    ds.fill_train_batch(0, &mut batch);
    let mut emb = vec![0.0f32; cfg.model.batch * cfg.model.num_sparse * dim];
    let grads = vec![0.001f32; emb.len()];

    bench("hotpath_data_fill_batch(128x(13+26))", quick)
        .throughput(cfg.model.batch as u64)
        .run(|| ds.fill_train_batch(12800, &mut batch));
    bench("hotpath_ps_gather(128x26xd16)", quick)
        .throughput((cfg.model.batch * cfg.model.num_sparse) as u64)
        .run(|| cluster.gather(&batch.indices, &mut emb));
    bench("hotpath_ps_sgd_update(128x26xd16)", quick)
        .throughput((cfg.model.batch * cfg.model.num_sparse) as u64)
        .run(|| cluster.sgd_update(&batch.indices, &grads, 0.01));

    let mut store = CheckpointStore::initial(&cluster, vec![]);
    bench("hotpath_checkpoint_full_save(77k rows)", quick)
        .throughput(cluster.total_params() as u64)
        .run(|| store.full_save(&cluster, vec![], 1, 128));
    bench("hotpath_checkpoint_restore_node", quick)
        .run(|| store.restore_node(&mut cluster, 3));

    let mut rng = Rng::new(5);
    let scores: Vec<f32> = (0..50_000).map(|_| rng.f32()).collect();
    let labels: Vec<f32> = (0..50_000)
        .map(|_| (rng.f64() < 0.5) as u32 as f32).collect();
    bench("hotpath_auc(50k samples)", quick)
        .throughput(50_000)
        .run(|| auc(&scores, &labels));

    let zipf = Zipf::new(1_000_000, 1.1);
    bench("hotpath_zipf_sample", quick)
        .run(|| zipf.sample(&mut rng));
}

// ---------------------------------------------------------------------------
// PJRT executables (requires `make artifacts`)
// ---------------------------------------------------------------------------

fn pjrt(quick: bool) {
    if !std::path::Path::new("artifacts/mini/manifest.json").exists() {
        println!("\n-- pjrt: SKIPPED (run `make artifacts`) --");
        return;
    }
    println!("\n-- pjrt: AOT executables from the Rust hot path --");
    let rt = Runtime::cpu().unwrap();
    for preset_name in ["mini", "kaggle_like", "terabyte_like"] {
        let model = rt.load_model("artifacts", preset_name).unwrap();
        let m = &model.manifest;
        let cfg = preset(preset_name).unwrap();
        let dim = m.emb_dim;
        let tables: Vec<TableInfo> = cfg.data.table_rows.iter()
            .map(|&rows| TableInfo { rows, dim }).collect();
        let mut cluster = PsCluster::new(tables, cfg.cluster.n_emb_ps, 7);
        let ds = SyntheticDataset::new(m.num_dense, &cfg.data);
        let mut batch = Batch::zeros(m.batch, m.num_dense, m.num_sparse);
        ds.fill_train_batch(0, &mut batch);
        let mut emb = vec![0.0f32; m.batch * m.num_sparse * dim];
        cluster.gather(&batch.indices, &mut emb);
        let mut params = model.init_params(1);

        bench(&format!("pjrt_train_step[{preset_name}]"), quick)
            .throughput(m.batch as u64)
            .run(|| {
                model.train_step(&batch.dense, &emb, &batch.labels, 0.05,
                                 &mut params).unwrap()
            });
        bench(&format!("pjrt_predict[{preset_name}]"), quick)
            .throughput(m.batch as u64)
            .run(|| model.predict(&batch.dense, &emb, &params).unwrap());
        let mut step_id = 0u64;
        bench(&format!("pjrt_e2e_step[{preset_name}] gather+step+scatter"), quick)
            .throughput(m.batch as u64)
            .run(|| {
                ds.fill_train_batch(step_id * m.batch as u64, &mut batch);
                cluster.gather(&batch.indices, &mut emb);
                let out = model.train_step(&batch.dense, &emb, &batch.labels,
                                           0.05, &mut params).unwrap();
                cluster.sgd_update(&batch.indices, &out.emb_grad, 0.05);
                step_id += 1;
            });
    }
}
