//! invariant-lint — the repo's concurrency-invariant build gate.
//!
//! `cargo run -p invariant-lint` scans the Rust tree (`rust/src`,
//! `rust/tests`, `examples`, `benches`) and fails (exit 1) on violations
//! of the four invariants that keep the unsafe surface enumerable and the
//! lock protocols analyzable (DESIGN.md "Concurrency model & unsafe
//! inventory"):
//!
//! * **R1 unsafe-confinement** — the `unsafe` keyword may appear only in
//!   the allowlisted modules (`cluster/lock.rs`, whose blocks are covered
//!   by the loom models + Miri/TSan lanes, and the benchmark's labeled
//!   volatile baseline). New unsafe anywhere else fails the build rather
//!   than slipping in unreviewed.
//! * **R2 no raw-memory reinterpretation** — `read_volatile` /
//!   `write_volatile` / `transmute` / `from_raw_parts[_mut]` / `data_ptr`
//!   are banned outside the bench baseline: shard data moves through
//!   `AtomicF32s` (atomic per-word bitcasts) and explicit little-endian
//!   byte codecs, never through pointer casts (PR 9 removed the last of
//!   them; this rule keeps them out).
//! * **R3 quiesce discipline** — any `rust/src` file invoking PS
//!   control-plane operations (`.kill_node(` / `.respawn_node(` /
//!   `.load_node(` / `.reset_node_to_init(` / `.snapshot_node(`) must
//!   state its quiesce contract: mention `PsQuiesce`/"quiesce" in the
//!   file (doc comments count — the *written contract* is what the rule
//!   enforces). Backend-mechanism modules that implement the control
//!   plane itself are allowlisted.
//! * **R4 lock-order tripwire** — per-node locks are only ever taken in
//!   ascending node order (that is the deadlock-freedom argument of the
//!   sharded data plane), so a `.rev(` adjacent to `node_read(` /
//!   `node_write(` / `wait_for(` is flagged for human review.
//!
//! Tokens are matched on a comment- and string-stripped view of each
//! file (a minimal Rust lexer below), so prose like "no `unsafe` here"
//! never trips R1/R2 — except R3's quiesce mention, which is
//! deliberately matched on the RAW source because documentation is
//! exactly what it demands.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

// ---------------------------------------------------------------------------
// configuration
// ---------------------------------------------------------------------------

/// Directories scanned, relative to the repo root.
const SCAN_DIRS: &[&str] = &["rust/src", "rust/tests", "examples", "benches"];

/// R1: the only files allowed to contain the `unsafe` keyword.
const UNSAFE_ALLOWLIST: &[&str] = &[
    // NodeLock's Send/Sync impls + UnsafeCell derefs: contracts documented
    // per block, modeled in cluster/models.rs, exercised under Miri/TSan
    "rust/src/cluster/lock.rs",
    // the labeled `seqlock=volatile-baseline` comparison loop
    "benches/cpr_bench.rs",
    // CountingAlloc's GlobalAlloc impl: pure delegation to System with a
    // thread-local counter side effect, SAFETY-documented per method
    "rust/src/testing/alloc.rs",
];

/// R2: banned raw-memory tokens and the files exempt from the ban.
const RAW_MEMORY_TOKENS: &[&str] = &[
    "read_volatile",
    "write_volatile",
    "transmute",
    "from_raw_parts",
    "from_raw_parts_mut",
    "data_ptr",
];
const RAW_MEMORY_ALLOWLIST: &[&str] = &["benches/cpr_bench.rs"];

/// R3: control-plane entry points and the mechanism modules exempt from
/// the quiesce-mention requirement (they ARE the mechanism).
const CONTROL_TOKENS: &[&str] = &[
    ".kill_node(",
    ".respawn_node(",
    ".load_node(",
    ".reset_node_to_init(",
    ".snapshot_node(",
];
const CONTROL_MECHANISM_ALLOWLIST: &[&str] = &[
    "rust/src/cluster/mod.rs",
    "rust/src/cluster/sharded.rs",
    "rust/src/cluster/threaded.rs",
    "rust/src/embedding/mod.rs",
];

/// R4: per-node lock acquisition points that must never sit next to a
/// descending iteration.
const LOCK_ACQUIRE_TOKENS: &[&str] = &["node_read(", "node_write(", "wait_for("];
/// Lines of context after a `.rev(` in which a lock acquisition trips R4.
const LOCK_ORDER_WINDOW: usize = 2;

// ---------------------------------------------------------------------------
// minimal Rust lexer: blank out comments and string/char literals
// ---------------------------------------------------------------------------

/// Return `src` with comments (line, nested block) and string-ish
/// literals (plain/byte/raw strings, char literals) replaced by spaces,
/// preserving newlines so byte offsets still map to the same lines.
/// Lifetimes (`'a`) pass through untouched.
pub fn strip_code(src: &str) -> String {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut out: Vec<char> = Vec::with_capacity(n);
    let blank = |c: char| if c == '\n' { '\n' } else { ' ' };
    let mut i = 0usize;
    while i < n {
        let c = b[i];
        let prev_ident =
            i > 0 && (b[i - 1].is_alphanumeric() || b[i - 1] == '_');
        // line comment (also covers //! and ///)
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            while i < n && b[i] != '\n' {
                out.push(' ');
                i += 1;
            }
            continue;
        }
        // block comment, nested
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let mut depth = 1;
            out.push(' ');
            out.push(' ');
            i += 2;
            while i < n && depth > 0 {
                if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                    depth += 1;
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                    depth -= 1;
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                } else {
                    out.push(blank(b[i]));
                    i += 1;
                }
            }
            continue;
        }
        // raw / raw-byte string: r"..."  r#"..."#  br##"..."##
        if !prev_ident && (c == 'r' || (c == 'b' && i + 1 < n && b[i + 1] == 'r'))
        {
            let mut j = i + if c == 'b' { 2 } else { 1 };
            let mut hashes = 0usize;
            while j < n && b[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if j < n && b[j] == '"' {
                for _ in i..=j {
                    out.push(' ');
                }
                let mut k = j + 1;
                while k < n {
                    if b[k] == '"' {
                        let mut m = 0usize;
                        while m < hashes && k + 1 + m < n && b[k + 1 + m] == '#'
                        {
                            m += 1;
                        }
                        if m == hashes {
                            for _ in 0..=hashes {
                                out.push(' ');
                            }
                            k += 1 + hashes;
                            break;
                        }
                    }
                    out.push(blank(b[k]));
                    k += 1;
                }
                i = k;
                continue;
            }
            // `r` / `br` not followed by a string: plain identifier chars
        }
        // plain or byte string
        if c == '"' || (c == 'b' && !prev_ident && i + 1 < n && b[i + 1] == '"')
        {
            let mut k = if c == 'b' {
                out.push(' ');
                i + 2
            } else {
                i + 1
            };
            out.push(' '); // opening quote
            while k < n {
                if b[k] == '\\' && k + 1 < n {
                    out.push(' ');
                    out.push(blank(b[k + 1]));
                    k += 2;
                    continue;
                }
                if b[k] == '"' {
                    out.push(' ');
                    k += 1;
                    break;
                }
                out.push(blank(b[k]));
                k += 1;
            }
            i = k;
            continue;
        }
        // char literal vs lifetime
        if c == '\'' {
            // escaped char literal: '\n' '\'' '\u{..}'
            if i + 1 < n && b[i + 1] == '\\' {
                out.push(' ');
                let mut k = i + 1;
                while k < n && b[k] != '\'' {
                    if b[k] == '\\' && k + 1 < n {
                        out.push(' ');
                        out.push(blank(b[k + 1]));
                        k += 2;
                    } else {
                        out.push(blank(b[k]));
                        k += 1;
                    }
                }
                if k < n {
                    out.push(' ');
                    k += 1;
                }
                i = k;
                continue;
            }
            // simple char literal: 'x' (next-next is the closing quote)
            if i + 2 < n && b[i + 2] == '\'' && b[i + 1] != '\'' {
                out.push(' ');
                out.push(' ');
                out.push(' ');
                i += 3;
                continue;
            }
            // lifetime / loop label: keep as-is
            out.push(c);
            i += 1;
            continue;
        }
        out.push(c);
        i += 1;
    }
    out.into_iter().collect()
}

// ---------------------------------------------------------------------------
// token search helpers
// ---------------------------------------------------------------------------

fn is_ident_byte(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Byte offsets of identifier-boundary occurrences of `word` (so
/// `undocumented_unsafe_blocks` does not count as `unsafe`).
fn find_word(text: &str, word: &str) -> Vec<usize> {
    let bytes = text.as_bytes();
    let mut found = Vec::new();
    let mut from = 0usize;
    while let Some(pos) = text[from..].find(word) {
        let at = from + pos;
        let end = at + word.len();
        let before_ok = at == 0 || !is_ident_byte(bytes[at - 1]);
        let after_ok = end >= bytes.len() || !is_ident_byte(bytes[end]);
        if before_ok && after_ok {
            found.push(at);
        }
        from = end;
    }
    found
}

/// Byte offsets of exact (non-word-boundary) occurrences of `needle` —
/// for method-call tokens like `.kill_node(`.
fn find_exact(text: &str, needle: &str) -> Vec<usize> {
    let mut found = Vec::new();
    let mut from = 0usize;
    while let Some(pos) = text[from..].find(needle) {
        found.push(from + pos);
        from += pos + needle.len();
    }
    found
}

fn line_of(text: &str, byte: usize) -> usize {
    text.as_bytes()[..byte.min(text.len())]
        .iter()
        .filter(|&&b| b == b'\n')
        .count()
        + 1
}

// ---------------------------------------------------------------------------
// rules
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}",
            self.file, self.line, self.rule, self.message
        )
    }
}

fn r1_unsafe_confined(rel: &str, stripped: &str, out: &mut Vec<Violation>) {
    if UNSAFE_ALLOWLIST.contains(&rel) {
        return;
    }
    for at in find_word(stripped, "unsafe") {
        out.push(Violation {
            file: rel.to_string(),
            line: line_of(stripped, at),
            rule: "R1-unsafe-confinement",
            message: "`unsafe` outside the allowlisted modules — move the \
                      code behind a safe primitive (cluster::seqlock, \
                      cluster::lock) or extend the reviewed allowlist"
                .to_string(),
        });
    }
}

fn r2_raw_memory(rel: &str, stripped: &str, out: &mut Vec<Violation>) {
    if RAW_MEMORY_ALLOWLIST.contains(&rel) {
        return;
    }
    for token in RAW_MEMORY_TOKENS {
        for at in find_word(stripped, token) {
            out.push(Violation {
                file: rel.to_string(),
                line: line_of(stripped, at),
                rule: "R2-raw-memory",
                message: format!(
                    "`{token}` on shard data is banned — use AtomicF32s \
                     (atomic word bitcasts) or the explicit little-endian \
                     byte codecs in checkpoint::{{wf32s,rf32s}}"
                ),
            });
        }
    }
}

fn r3_quiesce(rel: &str, raw: &str, stripped: &str, out: &mut Vec<Violation>) {
    if !rel.starts_with("rust/src/") || CONTROL_MECHANISM_ALLOWLIST.contains(&rel)
    {
        return;
    }
    let mentions_quiesce = raw.to_ascii_lowercase().contains("quiesce");
    if mentions_quiesce {
        return;
    }
    for token in CONTROL_TOKENS {
        if let Some(&at) = find_exact(stripped, token).first() {
            out.push(Violation {
                file: rel.to_string(),
                line: line_of(stripped, at),
                rule: "R3-quiesce",
                message: format!(
                    "control-plane call `{token}..)` in a file that never \
                     states its quiesce contract — document how callers are \
                     serialized against trainers (mention PsQuiesce), or \
                     route through a quiesce-holding coordinator"
                ),
            });
        }
    }
}

fn r4_lock_order(rel: &str, stripped: &str, out: &mut Vec<Violation>) {
    if !rel.starts_with("rust/src/") {
        return;
    }
    let lines: Vec<&str> = stripped.lines().collect();
    for (idx, line) in lines.iter().enumerate() {
        if !line.contains(".rev(") {
            continue;
        }
        let window_end = (idx + LOCK_ORDER_WINDOW).min(lines.len());
        let window = &lines[idx..window_end];
        for token in LOCK_ACQUIRE_TOKENS {
            if window.iter().any(|l| l.contains(token)) {
                out.push(Violation {
                    file: rel.to_string(),
                    line: idx + 1,
                    rule: "R4-lock-order",
                    message: format!(
                        "`.rev(` next to `{token}..)` — per-node locks must \
                         be acquired in ascending node order (the sharded \
                         data plane's deadlock-freedom argument)"
                    ),
                });
            }
        }
    }
}

pub fn lint_file(rel: &str, raw: &str) -> Vec<Violation> {
    let stripped = strip_code(raw);
    let mut out = Vec::new();
    r1_unsafe_confined(rel, &stripped, &mut out);
    r2_raw_memory(rel, &stripped, &mut out);
    r3_quiesce(rel, raw, &stripped, &mut out);
    r4_lock_order(rel, &stripped, &mut out);
    out
}

// ---------------------------------------------------------------------------
// tree walk + entry point
// ---------------------------------------------------------------------------

fn repo_root() -> PathBuf {
    // tools/invariant-lint/ -> repo root
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../..")
}

fn walk(dir: &Path, files: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(dir) else {
        return;
    };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            walk(&path, files);
        } else if path.extension().is_some_and(|e| e == "rs") {
            files.push(path);
        }
    }
}

/// Scan the whole tree; returns every violation found.
pub fn lint_tree(root: &Path) -> Vec<Violation> {
    let mut violations = Vec::new();
    let mut scanned = 0usize;
    for dir in SCAN_DIRS {
        let mut files = Vec::new();
        walk(&root.join(dir), &mut files);
        files.sort();
        for path in files {
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .to_string_lossy()
                .replace('\\', "/");
            let Ok(raw) = std::fs::read_to_string(&path) else {
                continue;
            };
            scanned += 1;
            violations.extend(lint_file(&rel, &raw));
        }
    }
    assert!(
        scanned > 0,
        "invariant-lint scanned no files under {} — wrong root?",
        root.display()
    );
    violations
}

fn main() -> ExitCode {
    let root = repo_root();
    let violations = lint_tree(&root);
    if violations.is_empty() {
        println!("invariant-lint: ok (R1 unsafe-confinement, R2 raw-memory, R3 quiesce, R4 lock-order)");
        return ExitCode::SUCCESS;
    }
    for v in &violations {
        eprintln!("{v}");
    }
    eprintln!("invariant-lint: {} violation(s)", violations.len());
    ExitCode::FAILURE
}

// ---------------------------------------------------------------------------
// self-tests: every rule must fire on a seeded violation and stay quiet
// on clean code; the lexer must keep prose from tripping token rules
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_fired(v: &[Violation]) -> Vec<&'static str> {
        v.iter().map(|x| x.rule).collect()
    }

    // ---- lexer ----

    #[test]
    fn lexer_blanks_comments_and_strings_preserving_lines() {
        let src = "let a = 1; // unsafe comment\nlet s = \"unsafe\";\n/* unsafe\nblock */ let b = 2;\n";
        let stripped = strip_code(src);
        assert_eq!(
            stripped.matches('\n').count(),
            src.matches('\n').count(),
            "newlines must survive stripping"
        );
        assert!(!stripped.contains("unsafe"));
        assert!(stripped.contains("let a = 1;"));
        assert!(stripped.contains("let b = 2;"));
    }

    #[test]
    fn lexer_handles_raw_strings_escapes_and_chars() {
        let src = r##"let r = r#"unsafe " transmute"#; let c = '\''; let q = "esc \" unsafe"; let lt: &'static str = x;"##;
        let stripped = strip_code(src);
        assert!(!stripped.contains("unsafe"));
        assert!(!stripped.contains("transmute"));
        assert!(stripped.contains("'static"), "lifetimes must pass through");
        assert!(stripped.contains("let lt: &"));
    }

    #[test]
    fn lexer_handles_nested_block_comments() {
        let src = "/* outer /* inner unsafe */ still comment */ fn f() {}";
        let stripped = strip_code(src);
        assert!(!stripped.contains("unsafe"));
        assert!(!stripped.contains("still comment"));
        assert!(stripped.contains("fn f() {}"));
    }

    // ---- R1 ----

    #[test]
    fn r1_fires_on_unsafe_outside_allowlist() {
        let v = lint_file(
            "rust/src/embedding/mod.rs",
            "fn f(p: *const f32) -> f32 { unsafe { *p } }",
        );
        assert!(rules_fired(&v).contains(&"R1-unsafe-confinement"), "{v:?}");
        assert_eq!(v[0].line, 1);
    }

    #[test]
    fn r1_spares_the_allowlist_and_prose() {
        assert!(lint_file(
            "rust/src/cluster/lock.rs",
            "unsafe impl<T: Send> Sync for NodeLock<T> {}",
        )
        .is_empty());
        // prose, string, and the clippy lint name must not count
        assert!(lint_file(
            "rust/src/lib.rs",
            "#![warn(clippy::undocumented_unsafe_blocks)]\n// no unsafe here\nlet s = \"unsafe\";",
        )
        .is_empty());
    }

    // ---- R2 ----

    #[test]
    fn r2_fires_on_raw_memory_tokens() {
        for token in RAW_MEMORY_TOKENS {
            let src = format!("fn f() {{ let x = std::ptr::{token}(p); }}");
            let v = lint_file("rust/src/cluster/seqlock.rs", &src);
            assert!(
                rules_fired(&v).contains(&"R2-raw-memory"),
                "{token} escaped R2"
            );
        }
    }

    #[test]
    fn r2_spares_the_bench_baseline_and_prose() {
        assert!(lint_file(
            "benches/cpr_bench.rs",
            "let v = unsafe { std::ptr::read_volatile(p) };",
        )
        .is_empty());
        assert!(lint_file(
            "rust/src/checkpoint/mod.rs",
            "// replaced a `from_raw_parts` cast with explicit LE bytes",
        )
        .is_empty());
    }

    // ---- R3 ----

    #[test]
    fn r3_fires_on_undocumented_control_plane_calls() {
        let v = lint_file(
            "rust/src/policy/save.rs",
            "fn save(c: &dyn PsControlPlane) { let s = c.snapshot_node(0); }",
        );
        assert!(rules_fired(&v).contains(&"R3-quiesce"), "{v:?}");
    }

    #[test]
    fn r3_satisfied_by_a_documented_contract_or_mechanism_file() {
        // the quiesce mention may live in a comment — that IS the contract
        assert!(lint_file(
            "rust/src/policy/save.rs",
            "//! Runs at the step barrier under the coordinator's PsQuiesce.\n\
             fn save(c: &dyn PsControlPlane) { let s = c.snapshot_node(0); }",
        )
        .is_empty());
        assert!(lint_file(
            "rust/src/cluster/threaded.rs",
            "fn t() { c.kill_node(1); }",
        )
        .is_empty());
        // tests/examples are out of R3 scope
        assert!(lint_file("rust/tests/serving.rs", "c.kill_node(1);").is_empty());
    }

    // ---- R4 ----

    #[test]
    fn r4_fires_on_descending_lock_acquisition() {
        let v = lint_file(
            "rust/src/trainer/mod.rs",
            "for n in (0..k).rev() {\n    let g = self.node_write(n);\n}",
        );
        assert!(rules_fired(&v).contains(&"R4-lock-order"), "{v:?}");
    }

    #[test]
    fn r4_spares_ascending_order_and_distant_rev() {
        assert!(lint_file(
            "rust/src/trainer/mod.rs",
            "for n in 0..k {\n    let g = self.node_write(n);\n}",
        )
        .is_empty());
        // a .rev( far from any lock acquisition (e.g. backprop layers)
        assert!(lint_file(
            "rust/src/runtime/native.rs",
            "for l in (0..n_top).rev() {\n    let w = self.layer(l);\n}\nfn other() {\n    let g = self.node_read(0);\n}",
        )
        .is_empty());
    }

    // ---- the real tree must be clean (this is the CI gate's substance) ----

    #[test]
    fn real_tree_has_no_violations() {
        let violations = lint_tree(&repo_root());
        assert!(
            violations.is_empty(),
            "invariant violations in the tree:\n{}",
            violations
                .iter()
                .map(|v| v.to_string())
                .collect::<Vec<_>>()
                .join("\n")
        );
    }

    // ---- end-to-end: a seeded violation in a fake tree is caught ----

    #[test]
    fn seeded_violation_fails_a_tree_scan() {
        let dir = std::env::temp_dir().join(format!(
            "invariant-lint-selftest-{}",
            std::process::id()
        ));
        let src_dir = dir.join("rust/src");
        std::fs::create_dir_all(&src_dir).unwrap();
        std::fs::write(
            src_dir.join("bad.rs"),
            "pub fn f(p: *const u8) -> u8 { unsafe { *p } }\n",
        )
        .unwrap();
        let violations = lint_tree(&dir);
        std::fs::remove_dir_all(&dir).ok();
        assert_eq!(violations.len(), 1, "{violations:?}");
        assert_eq!(violations[0].rule, "R1-unsafe-confinement");
        assert_eq!(violations[0].file, "rust/src/bad.rs");
    }
}
