//! End-to-end validation driver: train a ≈100M-parameter DLRM
//! (6.2M embedding rows × dim 16 + MLPs, the `large_100m` preset) for a
//! few hundred steps on the synthetic click log, with CPR-SSU
//! checkpointing and one injected Emb PS failure, logging the loss curve.
//!
//! This is the run recorded in EXPERIMENTS.md §End-to-end.
//!
//!     cargo run --release --example train_100m [-- --steps 500]

use anyhow::Result;

use cpr::config::{preset, Strategy};
use cpr::coordinator::{run_training, RunOptions};
use cpr::failure::uniform_schedule;
use cpr::policy::registry;
use cpr::runtime::Runtime;
use cpr::util::cli::Cli;
use cpr::util::rng::Rng;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cli = Cli::new("train_100m", "~100M-param end-to-end training run")
        .opt("steps", "500", "training steps (batch 128)")
        .opt("eval-every", "100", "AUC eval cadence")
        .parse(&args)?;
    let steps = cli.get_usize("steps")?;

    let mut cfg = preset("large_100m")?;
    cfg.data.train_samples = steps * cfg.model.batch;
    cfg.data.eval_samples = 16_000 - (16_000 % cfg.model.batch);
    cfg.checkpoint.strategy = Strategy::CprSsu;
    let spec = registry::spec(&cfg.checkpoint.strategy);
    println!("checkpoint policy [{}]: save={} recovery={} tracker={}",
             spec.name, spec.save, spec.recovery, spec.tracker.unwrap_or("-"));

    let total_params = cfg.data.total_rows() * cfg.model.emb_dim;
    println!("embedding parameters: {:.1} M rows x {} dim = {:.1} M params",
             cfg.data.total_rows() as f64 / 1e6, cfg.model.emb_dim,
             total_params as f64 / 1e6);

    let rt = Runtime::cpu()?;
    let model = rt.load_model(&cfg.artifacts_dir, &cfg.model.preset)?;
    println!("+ {} MLP params -> total {:.1} M",
             model.manifest.mlp_params(),
             (total_params + model.manifest.mlp_params()) as f64 / 1e6);

    let mut rng = Rng::new(100);
    let schedule = uniform_schedule(&mut rng, 1, cfg.cluster.t_total_h,
                                    cfg.cluster.n_emb_ps, 1);
    println!("failure scheduled at {:.1} h (node {:?})",
             schedule[0].time_h, schedule[0].victims);

    let t0 = std::time::Instant::now();
    let report = run_training(&model, &cfg, &RunOptions {
        schedule,
        eval_every: cli.get_usize("eval-every")?,
        log_every: 25,
        ..Default::default()
    })?;

    println!("\nloss curve:");
    for (step, loss) in &report.train_loss.points {
        println!("  step {step:>5}  loss {loss:.4}");
    }
    println!("\neval AUC curve:");
    for (step, a) in &report.eval_auc.points {
        println!("  step {step:>5}  auc {a:.4}");
    }
    println!("\nfinal AUC {:.4} | logloss {:.4} | PLS {:.4} | overhead {:.2}%",
             report.final_auc, report.final_logloss, report.pls,
             100.0 * report.overhead_frac);
    let secs = t0.elapsed().as_secs_f64();
    println!("wall {:.1}s | {:.0} samples/s",
             secs, (report.steps_executed * cfg.model.batch as u64) as f64 / secs);
    Ok(())
}
