//! Failure-tolerance comparison: every recovery strategy on the same
//! failure schedule (a miniature of the paper's Fig. 7).
//!
//!     cargo run --release --example failure_tolerance [-- --preset mini]
//!
//! Prints one row per strategy: checkpoint overhead, final AUC, PLS, and
//! whether CPR decided to fall back.

use anyhow::Result;

use cpr::config::{preset, Strategy};
use cpr::coordinator::{run_training, RunOptions};
use cpr::failure::uniform_schedule;
use cpr::runtime::Runtime;
use cpr::util::cli::Cli;
use cpr::util::rng::Rng;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cli = Cli::new("failure_tolerance", "strategy comparison (mini Fig. 7)")
        .opt("preset", "mini", "model preset")
        .opt("failures", "2", "failures to inject")
        .opt("fail-frac", "0.125", "fraction of Emb PS lost per failure")
        .opt("seed", "21", "schedule seed")
        .parse(&args)?;

    let base = preset(cli.get("preset"))?;
    let victims = ((base.cluster.n_emb_ps as f64 * cli.get_f64("fail-frac")?)
        .round() as usize).clamp(1, base.cluster.n_emb_ps);
    let mut rng = Rng::new(cli.get_u64("seed")?);
    let schedule = uniform_schedule(&mut rng, cli.get_usize("failures")?,
                                    base.cluster.t_total_h,
                                    base.cluster.n_emb_ps, victims);

    let rt = Runtime::cpu()?;
    let model = rt.load_model(&base.artifacts_dir, &base.model.preset)?;

    // no-failure reference first
    let clean = run_training(&model, &base, &RunOptions::default())?;
    println!("no-failure reference AUC: {:.5}\n", clean.final_auc);
    println!("{:<14} {:>10} {:>10} {:>9} {:>9} {:>6}",
             "strategy", "overhead%", "AUC", "dAUC", "PLS", "note");

    for strategy in [Strategy::Full, Strategy::PartialNaive,
                     Strategy::CprVanilla, Strategy::CprScar,
                     Strategy::CprMfu, Strategy::CprSsu] {
        let mut cfg = base.clone();
        cfg.checkpoint.strategy = strategy;
        let r = run_training(&model, &cfg, &RunOptions {
            schedule: schedule.clone(),
            ..Default::default()
        })?;
        println!("{:<14} {:>9.2}% {:>10.5} {:>9.5} {:>9.4} {:>6}",
                 r.strategy, 100.0 * r.overhead_frac, r.final_auc,
                 clean.final_auc - r.final_auc, r.pls,
                 if r.fell_back { "FB" } else { "" });
    }
    Ok(())
}
