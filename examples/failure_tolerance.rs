//! Failure-tolerance comparison: every registered checkpoint policy on
//! the same failure schedule (a miniature of the paper's Fig. 7, plus
//! the adaptive-interval policy the paper does not have).
//!
//!     cargo run --release --example failure_tolerance [-- --preset mini]
//!
//! The strategy list comes from the policy registry
//! (`cpr::policy::registry`), so a newly registered policy shows up here
//! without editing the example. Prints one row per policy: checkpoint
//! overhead, final AUC, PLS, and whether CPR decided to fall back.

use anyhow::Result;

use cpr::config::preset;
use cpr::coordinator::{run_training, RunOptions};
use cpr::failure::uniform_schedule;
use cpr::policy::registry;
use cpr::runtime::Runtime;
use cpr::util::cli::Cli;
use cpr::util::rng::Rng;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cli = Cli::new("failure_tolerance", "strategy comparison (mini Fig. 7)")
        .opt("preset", "mini", "model preset")
        .opt("failures", "2", "failures to inject")
        .opt("fail-frac", "0.125", "fraction of Emb PS lost per failure")
        .opt("seed", "21", "schedule seed")
        .parse(&args)?;

    let base = preset(cli.get("preset"))?;
    let victims = ((base.cluster.n_emb_ps as f64 * cli.get_f64("fail-frac")?)
        .round() as usize).clamp(1, base.cluster.n_emb_ps);
    let mut rng = Rng::new(cli.get_u64("seed")?);
    let schedule = uniform_schedule(&mut rng, cli.get_usize("failures")?,
                                    base.cluster.t_total_h,
                                    base.cluster.n_emb_ps, victims);

    let rt = Runtime::cpu()?;
    let model = rt.load_model(&base.artifacts_dir, &base.model.preset)?;

    // no-failure reference first
    let clean = run_training(&model, &base, &RunOptions::default())?;
    println!("no-failure reference AUC: {:.5}\n", clean.final_auc);
    println!("{:<14} {:<24} {:>10} {:>10} {:>9} {:>9} {:>6}",
             "strategy", "policy (save+tracker)", "overhead%", "AUC",
             "dAUC", "PLS", "note");

    for spec in registry::specs() {
        let mut cfg = base.clone();
        cfg.checkpoint.strategy = spec.strategy.clone();
        let r = run_training(&model, &cfg, &RunOptions {
            schedule: schedule.clone(),
            ..Default::default()
        })?;
        let policy = match spec.tracker {
            Some(t) => format!("{}+{t}", spec.save),
            None => spec.save.to_string(),
        };
        let note = if r.fell_back {
            "FB".to_string()
        } else if r.ledger.replans.is_empty() {
            String::new()
        } else {
            format!("{} replans", r.ledger.replans.len())
        };
        println!("{:<14} {:<24} {:>9.2}% {:>10.5} {:>9.5} {:>9.4} {:>6}",
                 r.strategy, policy, 100.0 * r.overhead_frac, r.final_auc,
                 clean.final_auc - r.final_auc, r.pls, note);
    }
    Ok(())
}
