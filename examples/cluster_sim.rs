//! Production-cluster analyses that need no PJRT: failure-trace survival
//! analysis (Fig. 3), fleet overhead breakdown (Fig. 4), and the
//! scalability projection (Fig. 13).
//!
//!     cargo run --release --example cluster_sim

use anyhow::Result;

use cpr::analysis::{fit_survival, hazard_curve, scalability_sweep, FailureModel};
use cpr::config::preset;
use cpr::failure::NodeHazard;
use cpr::policy::registry;
use cpr::sim::{simulate_fleet, FleetSimConfig};
use cpr::util::rng::Rng;

fn main() -> Result<()> {
    let mut rng = Rng::new(2026);

    // ---- the policy registry the fleet models approximate ----
    // Fig. 4/13 model the overhead of these policies analytically; the
    // training emulator runs the same registry for real.
    println!("== checkpoint-policy registry ==");
    for s in registry::specs() {
        println!("{:<13} save={:<18} recovery={:<16} tracker={:<5} {}",
                 s.name, s.save, s.recovery, s.tracker.unwrap_or("-"),
                 s.summary);
    }
    println!();

    // ---- Fig. 3: survival + hazard of 20k synthetic jobs ----
    println!("== Fig. 3 — failure-trace analysis (20k jobs) ==");
    let hazard = NodeHazard::default();
    for nodes in [16, 32, 64] {
        let ttfs = hazard.fleet_ttfs(&mut rng, 20_000, nodes, 500.0);
        let fit = fit_survival(&ttfs, 120.0, 48);
        println!("nodes={nodes:<3} MTBF={:>5.1} h  median={:>5.1} h  \
                  gamma(k={:.2}, θ={:.1})  fit RMSE={:.1}%",
                 fit.mtbf_h, fit.median_ttf_h, fit.shape, fit.scale,
                 100.0 * fit.rmse);
    }
    let ttfs = hazard.fleet_ttfs(&mut rng, 20_000, 16, 500.0);
    let hc = hazard_curve(&ttfs, 60.0, 12);
    println!("hazard (failures/h among survivors):");
    for (t, h) in hc {
        println!("  t={t:>5.1} h   {:.4}", h);
    }

    // ---- Fig. 4: fleet overhead breakdown ----
    println!("\n== Fig. 4 — checkpoint overhead breakdown (17k jobs) ==");
    let fleet = simulate_fleet(&mut rng, &FleetSimConfig::default());
    println!("mean overhead {:.1}% | machine-years wasted {:.0}",
             100.0 * fleet.mean_overhead_frac, fleet.machine_years_wasted);
    println!("{:>5} {:>8} {:>8} {:>8} {:>10} {:>8}",
             "pct", "save", "load", "lost", "reschedule", "total");
    for (p, s, l, lost, res, tot) in &fleet.breakdown {
        println!("{:>4.0}% {:>7.1}% {:>7.1}% {:>7.1}% {:>9.1}% {:>7.1}%",
                 p, 100.0 * s, 100.0 * l, 100.0 * lost, 100.0 * res,
                 100.0 * tot);
    }

    // ---- Fig. 13: scalability projection ----
    println!("\n== Fig. 13 — overhead vs. cluster size ==");
    let base = preset("mini")?.cluster;
    for (name, model) in [("linear-MTBF", FailureModel::LinearMtbf),
                          ("independent-p", FailureModel::IndependentP)] {
        println!("failure model: {name}");
        println!("{:>7} {:>10} {:>10}", "nodes", "full", "cpr");
        for p in scalability_sweep(&base, 0.1, model, 0.002,
                                   &[4, 8, 16, 32, 64, 128, 256]) {
            println!("{:>7} {:>9.2}% {:>9.2}%", p.n_nodes,
                     100.0 * p.full_overhead_frac,
                     100.0 * p.cpr_overhead_frac);
        }
    }
    Ok(())
}
