//! Production-cluster analyses that need no PJRT: failure-trace survival
//! analysis (Fig. 3), fleet overhead breakdown (Fig. 4), and the
//! scalability projection (Fig. 13).
//!
//!     cargo run --release --example cluster_sim
//!
//! With `--serve-qps [QPS]` it instead demos the online serving plane:
//! an open-loop Zipfian load generator reads a live cluster through the
//! three regimes (steady training writes, checkpoint capture under the
//! quiesce token, node failure + recovery) and prints the per-regime
//! latency table for both backends.
//!
//!     cargo run --release --example cluster_sim -- --serve-qps 50000

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use cpr::analysis::{fit_survival, hazard_curve, scalability_sweep, FailureModel};
use cpr::cluster::{
    PsBackend, PsControlPlane, PsDataPlane, PsServePlane, ShardedPs, ThreadedCluster,
};
use cpr::config::preset;
use cpr::embedding::{EmbOptimizer, PsCluster, TableInfo};
use cpr::failure::NodeHazard;
use cpr::policy::registry;
use cpr::serving::{LoadGen, Regime};
use cpr::sim::{simulate_fleet, FleetSimConfig};
use cpr::util::rng::Rng;

/// Drive one backend through steady / capture / recovery while the load
/// generator reads, and print its per-regime latency table.
fn serve_regimes<B: PsBackend + 'static>(kind: &str, shared: ShardedPs<B>, qps: f64) {
    let tables = shared.tables().to_vec();
    let n = shared.n_nodes();
    let t = tables.len();
    let dim = tables[0].dim;
    let lg = LoadGen::start(Arc::new(shared.clone()), tables.clone(), n,
                            qps, 4, 1.1, 2026);

    // -- steady: trainer-shaped writes racing the readers --
    let stop = Arc::new(AtomicBool::new(false));
    let writer = {
        let shared = shared.clone();
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut rng = Rng::new(11);
            let b = 256usize;
            let grads = vec![0.001f32; b * t * dim];
            let mut ticket = 0u64;
            while !stop.load(Ordering::Acquire) {
                let indices: Vec<u32> = (0..b * t)
                    .map(|i| rng.below(tables[i % t].rows as u64) as u32)
                    .collect();
                shared.apply_grads_ordered(ticket, &indices, 1, &grads, 0.01,
                                           EmbOptimizer::Sgd);
                ticket += 1;
                shared.publish_serve_view();
            }
        })
    };
    std::thread::sleep(Duration::from_millis(400));
    stop.store(true, Ordering::Release);
    writer.join().expect("writer");

    // -- capture: a checkpoint loop holds the quiesce token --
    lg.set_regime(Regime::Capture);
    let t0 = std::time::Instant::now();
    while t0.elapsed() < Duration::from_millis(400) {
        let q = shared.quiesce();
        for node in 0..n {
            std::hint::black_box(q.snapshot_node(node));
        }
    }

    // -- recovery: a node dies, serves NodeDown, then comes back --
    lg.set_regime(Regime::Recovery);
    {
        let q = shared.quiesce();
        q.kill_node(1);
    }
    std::thread::sleep(Duration::from_millis(200));
    {
        let q = shared.quiesce();
        q.respawn_node(1);
    }
    shared.publish_serve_view();
    std::thread::sleep(Duration::from_millis(200));

    let r = lg.stop();
    println!("\n-- {kind}: achieved {:.0}/s of {:.0} target --",
             r.achieved_qps, r.target_qps);
    println!("{:>9} {:>9} {:>9} {:>8} {:>8} {:>8} {:>8}",
             "regime", "requests", "nodedown", "p50us", "p95us", "p99us",
             "p999us");
    for reg in &r.regimes {
        println!("{:>9} {:>9} {:>9} {:>8} {:>8} {:>8} {:>8}",
                 reg.regime, reg.requests, reg.node_down, reg.p50_us,
                 reg.p95_us, reg.p99_us, reg.p999_us);
    }
}

fn serve_demo(qps: f64) -> Result<()> {
    let n = 4usize;
    let tables: Vec<TableInfo> =
        (0..4).map(|_| TableInfo { rows: 65_536, dim: 16 }).collect();
    println!("== serving-plane demo: {qps:.0} qps over {n} nodes, three regimes ==");
    serve_regimes("inproc", ShardedPs::new(PsCluster::new(tables.clone(), n, 7)), qps);
    serve_regimes("threaded",
                  ShardedPs::new(ThreadedCluster::new(tables, n, 7)), qps);
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    if let Some(i) = args.iter().position(|a| a == "--serve-qps") {
        let qps = args.get(i + 1).and_then(|v| v.parse().ok()).unwrap_or(20_000.0);
        return serve_demo(qps);
    }
    let mut rng = Rng::new(2026);

    // ---- the policy registry the fleet models approximate ----
    // Fig. 4/13 model the overhead of these policies analytically; the
    // training emulator runs the same registry for real.
    println!("== checkpoint-policy registry ==");
    for s in registry::specs() {
        println!("{:<13} save={:<18} recovery={:<16} tracker={:<5} {}",
                 s.name, s.save, s.recovery, s.tracker.unwrap_or("-"),
                 s.summary);
    }
    println!();

    // ---- Fig. 3: survival + hazard of 20k synthetic jobs ----
    println!("== Fig. 3 — failure-trace analysis (20k jobs) ==");
    let hazard = NodeHazard::default();
    for nodes in [16, 32, 64] {
        let ttfs = hazard.fleet_ttfs(&mut rng, 20_000, nodes, 500.0);
        let fit = fit_survival(&ttfs, 120.0, 48);
        println!("nodes={nodes:<3} MTBF={:>5.1} h  median={:>5.1} h  \
                  gamma(k={:.2}, θ={:.1})  fit RMSE={:.1}%",
                 fit.mtbf_h, fit.median_ttf_h, fit.shape, fit.scale,
                 100.0 * fit.rmse);
    }
    let ttfs = hazard.fleet_ttfs(&mut rng, 20_000, 16, 500.0);
    let hc = hazard_curve(&ttfs, 60.0, 12);
    println!("hazard (failures/h among survivors):");
    for (t, h) in hc {
        println!("  t={t:>5.1} h   {:.4}", h);
    }

    // ---- Fig. 4: fleet overhead breakdown ----
    println!("\n== Fig. 4 — checkpoint overhead breakdown (17k jobs) ==");
    let fleet = simulate_fleet(&mut rng, &FleetSimConfig::default());
    println!("mean overhead {:.1}% | machine-years wasted {:.0}",
             100.0 * fleet.mean_overhead_frac, fleet.machine_years_wasted);
    println!("{:>5} {:>8} {:>8} {:>8} {:>10} {:>8}",
             "pct", "save", "load", "lost", "reschedule", "total");
    for (p, s, l, lost, res, tot) in &fleet.breakdown {
        println!("{:>4.0}% {:>7.1}% {:>7.1}% {:>7.1}% {:>9.1}% {:>7.1}%",
                 p, 100.0 * s, 100.0 * l, 100.0 * lost, 100.0 * res,
                 100.0 * tot);
    }

    // ---- Fig. 13: scalability projection ----
    println!("\n== Fig. 13 — overhead vs. cluster size ==");
    let base = preset("mini")?.cluster;
    for (name, model) in [("linear-MTBF", FailureModel::LinearMtbf),
                          ("independent-p", FailureModel::IndependentP)] {
        println!("failure model: {name}");
        println!("{:>7} {:>10} {:>10}", "nodes", "full", "cpr");
        for p in scalability_sweep(&base, 0.1, model, 0.002,
                                   &[4, 8, 16, 32, 64, 128, 256]) {
            println!("{:>7} {:>9.2}% {:>9.2}%", p.n_nodes,
                     100.0 * p.full_overhead_frac,
                     100.0 * p.cpr_overhead_frac);
        }
    }
    Ok(())
}
