//! Quickstart: the smallest end-to-end use of the CPR library.
//!
//! Loads the AOT-compiled DLRM (L2/L1 artifacts), trains it for a short
//! single-epoch job on the synthetic click log with CPR-SSU checkpointing,
//! two data-parallel trainers, and two injected Emb PS failures, then
//! prints the loss curve + final AUC.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! The equivalent CLI run (the `--trainers N` flag picks the data-parallel
//! trainer count; `train_samples` must divide by `batch × N`):
//!
//!     cargo run --release --bin cpr -- train --preset mini \
//!         --strategy cpr-ssu --trainers 2 --backend threaded --failures 2

use anyhow::Result;

use cpr::config::{preset, Strategy};
use cpr::coordinator::{run_training, RunOptions};
use cpr::failure::uniform_schedule;
use cpr::policy::registry;
use cpr::runtime::Runtime;
use cpr::util::rng::Rng;

fn main() -> Result<()> {
    // 1. a job config: model architecture + synthetic dataset + emulated
    //    cluster constants. Presets mirror the paper's setups. The
    //    strategy is a key into the checkpoint-policy registry: it
    //    resolves to a JobPolicies bundle (save policy + recovery policy
    //    + tracker) the coordinator drives.
    let mut cfg = preset("mini")?;
    cfg.data.train_samples = 64_000; // 250 global steps at 2 trainers
    cfg.data.eval_samples = 16_000;
    cfg.cluster.n_trainers = 2; // two data-parallel trainer threads
    cfg.checkpoint.strategy = Strategy::CprSsu;
    cfg.checkpoint.target_pls = 0.1;
    let spec = registry::spec(&cfg.checkpoint.strategy);
    println!("policy bundle [{}]: save={} | recovery={} | tracker={}",
             spec.name, spec.save, spec.recovery,
             spec.tracker.unwrap_or("-"));

    // 2. the PJRT runtime executes the Python-free AOT artifacts.
    let rt = Runtime::cpu()?;
    let model = rt.load_model(&cfg.artifacts_dir, &cfg.model.preset)?;
    println!("platform: {} | MLP params: {} | embedding rows: {}",
             rt.platform(), model.manifest.mlp_params(),
             cfg.data.total_rows());

    // 3. a failure schedule: 2 failures, each killing 1 of the 8 Emb PS
    //    nodes, at uniform random emulated times (the paper's setup).
    let mut rng = Rng::new(42);
    let schedule = uniform_schedule(&mut rng, 2, cfg.cluster.t_total_h,
                                    cfg.cluster.n_emb_ps, 1);
    for ev in &schedule {
        println!("scheduled failure at {:5.1} h, victims {:?}",
                 ev.time_h, ev.victims);
    }

    // 4. run and report.
    let report = run_training(&model, &cfg, &RunOptions {
        schedule,
        eval_every: 100,
        ..Default::default()
    })?;

    println!("\ntrain loss:");
    for (step, loss) in &report.train_loss.points {
        if step % 100 == 0 {
            println!("  step {step:>5}  loss {loss:.4}");
        }
    }
    println!("\neval AUC:");
    for (step, a) in &report.eval_auc.points {
        println!("  step {step:>5}  auc {a:.4}");
    }
    if let Some(p) = &report.plan {
        println!("\nCPR plan: interval {:.1} h (expected PLS {:.3})",
                 p.t_save_h, p.expected_pls);
    }
    println!("\nfinal AUC {:.4} | overhead {:.2}% | PLS {:.4} | wall {:.1}s",
             report.final_auc, 100.0 * report.overhead_frac, report.pls,
             report.wall_secs);
    Ok(())
}
